//! Integration: the continuous-batching serve engine end to end.
//!
//! The host decode backend needs no compiled artifacts, so unlike the
//! runtime/eval integration suites everything here runs in a bare checkout;
//! the one artifact-dependent test skips itself like the others do.

use std::sync::Arc;

use silq::forward::{decode_greedy, ForwardBackend, HostForward};
use silq::hostmodel::host_test_params;
use silq::model::ParamStore;
use silq::serve::{
    serve_inline, AdmissionQueue, ArtifactBackend, CacheStore, DecodeBackend, GenRequest,
    HostBackend, HostCfg, Scheduler, ServeHandle, ServeStats,
};
use silq::util::Rng;

fn host_cfg(act_dynamic: bool) -> HostCfg {
    let spec = if act_dynamic { "w4a8kv8" } else { "w4a8kv8:statacts" };
    HostCfg {
        vocab: 256,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 24,
        policy: spec.parse().unwrap(),
        rope_theta: 10000.0,
    }
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n).map(|i| vec![1, 3, 22 + (i % 4) as i32, 10, 128 + (i % 32) as i32, 4]).collect()
}

/// Continuous batching: with 2 lanes and 3 requests, the third must enter a
/// lane as soon as the short request finishes — strictly before the long
/// request (and therefore the initial batch) has drained.
#[test]
fn admits_queued_request_before_batch_drains() {
    let cfg = host_cfg(true);
    let params = host_test_params(&cfg, 11);
    let backend = HostBackend::new(cfg, 2, &params, CacheStore::Int8).unwrap();
    let ps = prompts(3);
    // ignore_eos makes every request decode its exact budget, so the step
    // accounting below is deterministic even for an untrained model
    let reqs = vec![
        GenRequest::new(1, ps[0].clone(), 10).ignore_eos(),
        GenRequest::new(2, ps[1].clone(), 2).ignore_eos(),
        GenRequest::new(3, ps[2].clone(), 2).ignore_eos(),
    ];
    let (results, stats) = serve_inline(backend, 2, reqs).unwrap();
    assert_eq!(results.len(), 3);
    let by = |id: u64| results.iter().find(|r| r.id == id).unwrap();
    let (r1, r2, r3) = (by(1), by(2), by(3));
    assert!(
        r3.admitted_step < r1.finished_step,
        "request 3 admitted at step {} but the batch only drained at step {}",
        r3.admitted_step,
        r1.finished_step
    );
    assert!(r3.admitted_step >= r2.finished_step);
    assert!(stats.mean_queue_depth() > 0.0);
    assert!(stats.batch_occupancy() > 0.0);
}

/// The INT8 KV pool must produce token-identical greedy output to the f32
/// cache path — the pack/unpack losslessness invariant, end to end through
/// the serve engine, in both the dynamic and static cache-step modes.
/// Since the integer-kernel PR the two stores attend with different
/// arithmetic (exact i32 over the slab vs f32 over fake-quant rows), so
/// this identity rides on greedy margins dwarfing float rounding — which
/// they do by ~4 orders of magnitude on these models; a failure here means
/// the paths diverged beyond rounding, not an unlucky tie.
#[test]
fn int8_kv_pool_matches_f32_cache_token_for_token() {
    for act_dynamic in [true, false] {
        let cfg = host_cfg(act_dynamic);
        let params = host_test_params(&cfg, 13);
        let ps = prompts(6);
        let mk_reqs =
            || ps.iter().enumerate().map(|(i, p)| GenRequest::new(i as u64, p.clone(), 6)).collect();

        let b_f32 = HostBackend::new(cfg.clone(), 3, &params, CacheStore::F32).unwrap();
        let b_i8 = HostBackend::new(cfg.clone(), 3, &params, CacheStore::Int8).unwrap();
        let (mut r_f32, _) = serve_inline(b_f32, 3, mk_reqs()).unwrap();
        let (mut r_i8, _) = serve_inline(b_i8, 3, mk_reqs()).unwrap();
        r_f32.sort_by_key(|r| r.id);
        r_i8.sort_by_key(|r| r.id);
        assert_eq!(r_f32.len(), 6);
        for (a, b) in r_f32.iter().zip(&r_i8) {
            assert!(!a.generated().is_empty());
            assert_eq!(
                a.generated(),
                b.generated(),
                "act_dynamic={act_dynamic} req {}: int8 KV pool diverged from f32 cache",
                a.id
            );
        }
    }
}

/// The serve engine and the eval-style `ForwardBackend` decode driver are
/// two fronts over the same hostmodel forward: the same prompts greedy-
/// decoded through both must emit identical tokens.
#[test]
fn serve_engine_matches_forward_trait_decode() {
    for store in [CacheStore::Int8, CacheStore::F32] {
        let cfg = host_cfg(true);
        let params = host_test_params(&cfg, 23);
        let ps = prompts(4);

        // (a) through the continuous-batching scheduler
        let reqs = ps
            .iter()
            .enumerate()
            .map(|(i, p)| GenRequest::new(i as u64, p.clone(), 5).ignore_eos())
            .collect();
        let serve_backend = HostBackend::new(cfg.clone(), 4, &params, store).unwrap();
        let (mut served, _) = serve_inline(serve_backend, 4, reqs).unwrap();
        served.sort_by_key(|r| r.id);

        // (b) through the shared incremental decode driver
        let mut fwd = HostForward::new(cfg, 4, &params, store).unwrap();
        let views: Vec<&[i32]> = ps.iter().map(|p| p.as_slice()).collect();
        let gen = decode_greedy(&mut fwd, &views, 5).unwrap();

        for (r, g) in served.iter().zip(&gen) {
            assert_eq!(
                r.generated(),
                &g[..],
                "store {store:?}: serve engine diverged from the forward-trait driver"
            );
        }
    }
}

/// Batched full-sequence scoring and incremental decode agree through the
/// trait surface: the next token after a prefix is the argmax of the
/// batched logits at the prefix's last position.
#[test]
fn batch_logits_agree_with_incremental_next_token() {
    let cfg = host_cfg(false); // static steps: the trained-scalar cache mode
    let params = host_test_params(&cfg, 29);
    let mut fwd = HostForward::new(cfg, 2, &params, CacheStore::F32).unwrap();
    let (s, v) = (fwd.seq_len(), fwd.vocab());
    let ps = prompts(2);
    let views: Vec<&[i32]> = ps.iter().map(|p| p.as_slice()).collect();

    let logits = fwd.batch_logits(&views).unwrap();
    let gen = decode_greedy(&mut fwd, &views, 1).unwrap();
    for (r, p) in ps.iter().enumerate() {
        let base = (r * s + p.len() - 1) * v;
        let batch_next = silq::evalharness::decode::argmax(&logits[base..base + v]) as i32;
        assert_eq!(gen[r][0], batch_next, "row {r}");
    }
}

/// The engine is shared soundly across threads: multiple producers block on
/// the bounded queue while the scheduler drains it from a worker thread.
#[test]
fn multithreaded_producers_share_the_engine() {
    let cfg = host_cfg(true);
    let params = host_test_params(&cfg, 17);
    let backend = HostBackend::new(cfg, 4, &params, CacheStore::Int8).unwrap();
    // queue cap far below the request count forces real backpressure
    let handle = ServeHandle::spawn(backend, 4, 3).unwrap();
    let mut producers = vec![];
    for p in 0..4u64 {
        let q = handle.queue();
        producers.push(std::thread::spawn(move || {
            for i in 0..8u64 {
                let id = p * 8 + i;
                let prompt = vec![1, 3, 22 + (id % 4) as i32, 10, 128 + (id % 16) as i32, 4];
                q.submit(GenRequest::new(id, prompt, 3).ignore_eos()).unwrap();
            }
        }));
    }
    for t in producers {
        t.join().unwrap();
    }
    let (results, stats) = handle.finish().unwrap();
    assert_eq!(results.len(), 32);
    assert_eq!(stats.completed, 32);
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 32, "every request id served exactly once");
    assert!(results.iter().all(|r| !r.generated().is_empty()));
}

/// Artifact-gated smoke: the compiled-graph backend serves a load run
/// through the same scheduler (skips when artifacts are not built).
#[test]
fn artifact_backend_serves_when_built() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let eng = silq::runtime::Engine::new("artifacts").expect("engine");
    let art = "tiny_a8d-c8-w4_fwd";
    let spec = eng.module(art).unwrap().spec.clone();
    let mc = eng.manifest.model("tiny").unwrap().clone();
    let mut rng = Rng::new(0);
    let params = ParamStore::init(&spec, &mc, &mut rng);
    let backend = ArtifactBackend::new(&eng, art, &params).unwrap();
    let lanes = 4.min(backend.lanes());

    let queue = Arc::new(AdmissionQueue::new(8));
    for (i, p) in prompts(8).into_iter().enumerate() {
        queue.submit(GenRequest::new(i as u64, p, 4)).unwrap();
    }
    queue.close();
    let mut stats = ServeStats::new(lanes);
    let mut sched = Scheduler::new(backend, lanes).unwrap();
    let results = sched.run(&queue, &mut stats).unwrap();
    assert_eq!(results.len(), 8);
    // an untrained model may emit EOS early; the budget still bounds it
    assert!(results.iter().all(|r| (1..=4).contains(&r.generated().len())));
    assert!(stats.tokens_per_sec() > 0.0);
}
