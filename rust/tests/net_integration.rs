//! Wire integration: the HTTP front-end against an in-process server over
//! real sockets, pinning the ISSUE-level guarantees one scenario at a
//! time:
//!
//! * tokens served over the wire are **bit-identical** to `serve_inline`
//!   on the same backend (quantized and fp16 alike — transport must never
//!   touch the numerics);
//! * a client disconnect mid-stream cancels the lane and frees its KV
//!   slot, and the next request completes on the freed lane;
//! * a full admission queue answers `429` deterministically (lane and
//!   queue both provably occupied first), carrying a `Retry-After` hint;
//! * a stalled (slowloris) or oversized request is refused by the guards
//!   (408/431/413) instead of pinning a handler slot;
//! * a queued request whose TTFT deadline expires is shed with `503`;
//! * `/healthz`, `/metrics` and the 400/404 error paths.
//!
//! The tests share one process (and so the global telemetry registry and
//! worker pool); `serial()` serializes them so counter waits and
//! per-server tallies never interleave.

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use silq::hostmodel::host_test_params;
use silq::net::{client as netclient, http, Json, NetReport, Server, ServerCfg};
use silq::serve::{
    serve_inline, CacheStore, DecodeBackend, GenRequest, HostBackend, HostCfg, ServeOutcome,
};

fn serial() -> std::sync::MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

fn test_cfg(prec: &str, seq_len: usize) -> HostCfg {
    HostCfg {
        vocab: 256,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len,
        policy: prec.parse().unwrap(),
        rope_theta: 10000.0,
    }
}

fn backend(prec: &str, seq_len: usize, lanes: usize) -> HostBackend {
    let cfg = test_cfg(prec, seq_len);
    let store = CacheStore::for_policy(&cfg.policy);
    let params = host_test_params(&cfg, 71);
    HostBackend::new(cfg, lanes, &params, store).unwrap()
}

/// Bind an ephemeral port, run the server on a worker thread, hand back
/// the address, the drain flag, and the join handle for the outcome.
fn spawn_server(
    prec: &str,
    seq_len: usize,
    lanes: usize,
    queue_cap: usize,
) -> (
    String,
    Arc<AtomicBool>,
    std::thread::JoinHandle<(ServeOutcome<HostBackend>, NetReport)>,
) {
    spawn_server_with(prec, seq_len, lanes, queue_cap, 5000)
}

/// [`spawn_server`] with an explicit slowloris guard window (the stall
/// regression test needs a short one).
fn spawn_server_with(
    prec: &str,
    seq_len: usize,
    lanes: usize,
    queue_cap: usize,
    header_timeout_ms: u64,
) -> (
    String,
    Arc<AtomicBool>,
    std::thread::JoinHandle<(ServeOutcome<HostBackend>, NetReport)>,
) {
    let b = backend(prec, seq_len, lanes);
    let server = Server::bind(ServerCfg {
        addr: "127.0.0.1:0".into(),
        lanes,
        queue_cap,
        max_conns: 16,
        default_max_new: 4,
        header_timeout_ms,
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let flag = server.shutdown_flag();
    let worker = std::thread::spawn(move || server.run(b).unwrap());
    (addr, flag, worker)
}

fn prompt_of(id: u64) -> Vec<i32> {
    let plen = 1 + (id % 5) as usize;
    (0..plen as i32).map(|k| 1 + (id as i32 * 31 + k * 7) % 250).collect()
}

fn budget_of(id: u64) -> usize {
    (id % 4 + 1) as usize
}

#[test]
fn wire_tokens_match_serve_inline() {
    let _g = serial();
    silq::obs::set_enabled(true);
    // both the INT8-cache quantized policy and fp16: the transport layer
    // must be numerics-invariant for every serving configuration
    for prec in ["w4a8kv8", "fp16"] {
        let (lanes, seq_len, n) = (2usize, 24usize, 10u64);
        let reqs: Vec<GenRequest> = (0..n)
            .map(|id| GenRequest::new(id, prompt_of(id), budget_of(id)).ignore_eos())
            .collect();
        let (inline_results, _) = serve_inline(backend(prec, seq_len, lanes), lanes, reqs).unwrap();
        let expected: HashMap<u64, Vec<i32>> =
            inline_results.iter().map(|r| (r.id, r.generated().to_vec())).collect();

        let (addr, _flag, worker) = spawn_server(prec, seq_len, lanes, 8);
        for id in 0..n {
            let stream = id % 2 == 0;
            let body =
                netclient::completion_body(id, &prompt_of(id), budget_of(id), true, stream);
            if stream {
                let o = netclient::complete_streaming(&addr, &body, None).unwrap();
                assert_eq!(o.status, 200);
                assert_eq!(o.tokens, expected[&id], "{prec}: streamed tokens diverged on {id}");
                assert!(o.ttft_ms.is_finite() && o.ttft_ms > 0.0);
                let done = o.done.expect("terminal frame missing");
                assert_eq!(
                    done.get("generated").and_then(Json::as_i32_arr).unwrap(),
                    expected[&id],
                    "{prec}: done frame diverged from the stream on {id}"
                );
                assert_eq!(done.get("error"), Some(&Json::Null));
            } else {
                let o = netclient::complete_buffered(&addr, &body).unwrap();
                assert_eq!(o.status, 200);
                assert_eq!(o.tokens, expected[&id], "{prec}: buffered tokens diverged on {id}");
            }
        }
        // drain through the endpoint (the flag path is covered elsewhere)
        assert_eq!(netclient::shutdown(&addr).unwrap(), 200);
        let ((results, stats, backend), net) = worker.join().unwrap();
        assert_eq!(results.len(), n as usize);
        assert_eq!((stats.completed, stats.rejected, stats.cancelled), (n as usize, 0, 0));
        assert_eq!(net.streams, n / 2);
        assert_eq!((net.disconnects, net.rejected_429), (0, 0));
        assert!(backend.all_slots_free(), "{prec}: drain left a slot allocated");
        assert_eq!(backend.kv_bytes(), 0);
    }
}

#[test]
fn disconnect_cancels_lane_and_next_request_completes() {
    let _g = serial();
    silq::obs::set_enabled(true);
    let seq_len = 32;
    // one lane: B can only complete if A's cancellation actually frees it
    let (addr, flag, worker) = spawn_server("w4a8kv8", seq_len, 1, 4);
    let body_a = netclient::completion_body(1, &[5, 6], seq_len * 2, true, true);
    let a = netclient::complete_streaming(&addr, &body_a, Some(2)).unwrap();
    assert!(a.disconnected);
    assert_eq!(a.tokens.len(), 2);
    assert!(a.ttft_ms.is_finite());
    let body_b = netclient::completion_body(2, &[7, 8], 3, true, false);
    let b = netclient::complete_buffered(&addr, &body_b).unwrap();
    assert_eq!(b.status, 200);
    assert_eq!(b.tokens.len(), 3, "request after the disconnect must run to completion");
    assert_eq!(b.done.unwrap().get("error"), Some(&Json::Null));
    flag.store(true, Ordering::SeqCst);
    let ((results, stats, backend), net) = worker.join().unwrap();
    assert_eq!((stats.completed, stats.cancelled), (1, 1));
    let ra = results.iter().find(|r| r.id == 1).unwrap();
    assert!(ra.error.as_deref().unwrap().contains("cancel"), "{:?}", ra.error);
    assert!(
        ra.generated().len() < seq_len - 2,
        "cancellation did not stop the decode ({} tokens)",
        ra.generated().len()
    );
    assert_eq!(net.disconnects, 1);
    assert!(backend.all_slots_free(), "cancelled lane leaked its KV slot");
    assert_eq!(backend.kv_bytes(), 0);
}

#[test]
fn queue_full_answers_429() {
    let _g = serial();
    silq::obs::set_enabled(true);
    use silq::obs::Counter;
    let e0 = silq::obs::get(Counter::ServeEnqueued);
    // a long window keeps A decoding while B1/B2 arrive: one lane is
    // occupied by A (first token observed on the wire), the one-slot
    // queue by B1 (enqueue observed via the counter) — so B2's 429 is
    // deterministic, not a race
    let seq_len = 768;
    let (addr, flag, worker) = spawn_server("w4a8kv8", seq_len, 1, 1);
    let body_a = netclient::completion_body(1, &[5, 6], seq_len * 2, true, true);
    let mut a = std::net::TcpStream::connect(&addr).unwrap();
    write!(
        a,
        "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body_a}",
        body_a.len()
    )
    .unwrap();
    a.flush().unwrap();
    let mut ra = BufReader::new(a.try_clone().unwrap());
    let (status, _) = http::read_response_head(&mut ra).unwrap();
    assert_eq!(status, 200);
    assert!(http::read_chunk(&mut ra).unwrap().is_some(), "no first token frame");
    // A is in the lane; B1 fills the queue from its own thread (its
    // handler blocks on the result until A leaves the lane)
    let addr2 = addr.clone();
    let b1 = std::thread::spawn(move || {
        let body = netclient::completion_body(2, &[7], 2, true, false);
        netclient::complete_buffered(&addr2, &body).unwrap()
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    while silq::obs::get(Counter::ServeEnqueued) - e0 < 2 {
        assert!(Instant::now() < deadline, "B1 never reached the queue");
        std::thread::sleep(Duration::from_millis(1));
    }
    // lane busy + queue full: B2 bounces immediately, with a backoff hint
    let body = netclient::completion_body(3, &[9], 2, true, false);
    let b2 = netclient::complete_buffered(&addr, &body).unwrap();
    assert_eq!(b2.status, 429, "{:?}", b2.done);
    let text = b2.done.as_ref().and_then(|d| d.get("error")).and_then(Json::as_str).unwrap();
    assert!(text.contains("queue"));
    assert!(b2.retry_after_ms.unwrap() >= 1, "429 must carry a retry_after_ms estimate");
    // hang up A: the cancel frees the lane, B1 gets admitted and finishes
    drop(ra);
    drop(a);
    let b1 = b1.join().unwrap();
    assert_eq!(b1.status, 200);
    assert_eq!(b1.tokens.len(), 2);
    flag.store(true, Ordering::SeqCst);
    let ((_, stats, backend), net) = worker.join().unwrap();
    assert_eq!(net.rejected_429, 1);
    assert_eq!(stats.cancelled, 1);
    assert!(backend.all_slots_free());
}

#[test]
fn health_metrics_and_error_paths() {
    let _g = serial();
    silq::obs::set_enabled(true);
    let (addr, flag, worker) = spawn_server("w4a8kv8", 24, 2, 4);
    let (s, body) = netclient::get(&addr, "/healthz").unwrap();
    assert_eq!(s, 200);
    assert_eq!(Json::parse(&body).unwrap().get("status").and_then(Json::as_str), Some("ok"));
    // one streamed completion so the wire-TTFT summary has a sample
    let body_r = netclient::completion_body(1, &[3, 4], 2, true, true);
    let o = netclient::complete_streaming(&addr, &body_r, None).unwrap();
    assert_eq!((o.status, o.tokens.len()), (200, 2));
    let (s, body) = netclient::get(&addr, "/metrics").unwrap();
    assert_eq!(s, 200);
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some("silq.metrics.v1"));
    assert!(doc.get("counters").is_some(), "metrics dropped the counter map");
    let count = doc.get("wire_ttft").and_then(|w| w.get("count")).and_then(Json::as_u64);
    assert!(count.unwrap() >= 1, "wire TTFT sample missing from /metrics");
    // error paths: unknown endpoint, malformed body, missing/empty prompt
    assert_eq!(netclient::get(&addr, "/nope").unwrap().0, 404);
    let (s, text) = netclient::request(&addr, "POST", "/v1/completions", "{not json").unwrap();
    assert_eq!(s, 400);
    assert!(text.contains("bad json"));
    let (s, text) =
        netclient::request(&addr, "POST", "/v1/completions", "{\"max_tokens\":2}").unwrap();
    assert_eq!(s, 400);
    assert!(text.contains("prompt"));
    let (s, _) = netclient::request(&addr, "POST", "/v1/completions", "{\"prompt\":[]}").unwrap();
    assert_eq!(s, 400, "the queue's Invalid must map to 400");
    flag.store(true, Ordering::SeqCst);
    let ((_, stats, backend), net) = worker.join().unwrap();
    assert_eq!(stats.completed, 1);
    assert_eq!(net.streams, 1);
    assert!(backend.all_slots_free());
}

#[test]
fn slowloris_and_oversized_requests_hit_the_guards() {
    let _g = serial();
    silq::obs::set_enabled(true);
    // a short guard window so the stall answers fast
    let (addr, flag, worker) = spawn_server_with("w4a8kv8", 24, 1, 4, 150);

    // slowloris: deliver half a request head, then stall past the window
    let mut s = std::net::TcpStream::connect(&addr).unwrap();
    write!(s, "POST /v1/completions HTTP/1.1\r\nHost: t\r\n").unwrap();
    s.flush().unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let (status, _) = http::read_response_head(&mut r).unwrap();
    assert_eq!(status, 408, "a stalled request head must be timed out");
    drop(r);
    drop(s);

    // unbounded request line: refused at the line cap, not buffered
    let mut s = std::net::TcpStream::connect(&addr).unwrap();
    s.write_all(b"GET /").unwrap();
    s.write_all(&vec![b'a'; 9 * 1024]).unwrap();
    s.flush().unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let (status, _) = http::read_response_head(&mut r).unwrap();
    assert_eq!(status, 431, "an oversized request line must be refused");
    drop(r);
    drop(s);

    // oversized body: refused from the declared length alone
    let mut s = std::net::TcpStream::connect(&addr).unwrap();
    write!(
        s,
        "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
        2 * 1024 * 1024
    )
    .unwrap();
    s.flush().unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let (status, _) = http::read_response_head(&mut r).unwrap();
    assert_eq!(status, 413, "an oversized body must be refused");
    drop(r);
    drop(s);

    // the server is still healthy and serving after the abuse
    let body = netclient::completion_body(1, &[3, 4], 2, true, false);
    let o = netclient::complete_buffered(&addr, &body).unwrap();
    assert_eq!((o.status, o.tokens.len()), (200, 2));

    flag.store(true, Ordering::SeqCst);
    let ((_, stats, backend), net) = worker.join().unwrap();
    assert_eq!(stats.completed, 1);
    assert_eq!(net.guard_rejects, 3, "each guarded refusal must be tallied");
    assert!(backend.all_slots_free());
}

#[test]
fn queued_request_past_its_ttft_deadline_is_shed_with_503() {
    let _g = serial();
    silq::obs::set_enabled(true);
    use silq::obs::Counter;
    let e0 = silq::obs::get(Counter::ServeEnqueued);
    // same occupancy trick as the 429 test: A holds the single lane with
    // a long decode while B waits in the queue with an already-expired
    // TTFT deadline — the next step boundary must shed B, not admit it
    let seq_len = 768;
    let (addr, flag, worker) = spawn_server("w4a8kv8", seq_len, 1, 4);
    let body_a = netclient::completion_body(1, &[5, 6], seq_len * 2, true, true);
    let mut a = std::net::TcpStream::connect(&addr).unwrap();
    write!(
        a,
        "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body_a}",
        body_a.len()
    )
    .unwrap();
    a.flush().unwrap();
    let mut ra = BufReader::new(a.try_clone().unwrap());
    let (status, _) = http::read_response_head(&mut ra).unwrap();
    assert_eq!(status, 200);
    assert!(http::read_chunk(&mut ra).unwrap().is_some(), "no first token frame");

    // B: expired before it ever reaches the queue (ttft_deadline_ms: 0);
    // streaming mode on purpose — the shed must preempt the SSE 200
    let body_b = netclient::completion_body_ext(
        2, &[7], 4, true, true, Some("interactive"), None, Some(0),
    );
    let addr2 = addr.clone();
    let b = std::thread::spawn(move || {
        netclient::complete_streaming(&addr2, &body_b, None).unwrap()
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    while silq::obs::get(Counter::ServeEnqueued) - e0 < 2 {
        assert!(Instant::now() < deadline, "B never reached the queue");
        std::thread::sleep(Duration::from_millis(1));
    }
    // free the lane: A hangs up, the next step boundary processes the
    // queue and sheds B
    drop(ra);
    drop(a);
    let b = b.join().unwrap();
    assert_eq!(b.status, 503, "{:?}", b.done);
    let doc = b.done.expect("shed answer must carry a JSON body");
    assert_eq!(doc.get("reason").and_then(Json::as_str), Some("deadline_shed"));
    assert!(b.retry_after_ms.unwrap() >= 1, "shed must carry a backoff hint");
    assert!(b.tokens.is_empty(), "a shed request must never decode");

    flag.store(true, Ordering::SeqCst);
    let ((results, stats, backend), net) = worker.join().unwrap();
    assert_eq!((stats.deadline_shed, stats.cancelled), (1, 1));
    assert_eq!(net.shed_503, 1);
    let rb = results.iter().find(|r| r.id == 2).unwrap();
    assert!(rb.error.as_deref().unwrap().contains("ttft deadline"), "{:?}", rb.error);
    assert!(backend.all_slots_free(), "shed request leaked a KV slot");
    assert_eq!(backend.kv_bytes(), 0);
}
