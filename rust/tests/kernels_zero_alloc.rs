//! Steady-state decode performs **zero heap allocations** — the
//! `DecodeScratch` acceptance criterion of the integer-kernel PR, pinned
//! with a counting global allocator.
//!
//! This file is its own test binary on purpose: the allocator counter is
//! global, so no unrelated tests may run concurrently while the decode
//! loop is being measured.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use silq::hostmodel::{host_test_params, CacheStore, HostCfg, HostModel, KvLayout};
use silq::kernels::DecodeScratch;
use silq::policy::QuantPolicy;

/// Paged geometry for the paged-path sweeps: pages smaller than the
/// window so the decode loop crosses page boundaries (and lazily binds
/// fresh pages) inside the counted window.
fn paged() -> KvLayout {
    KvLayout::Paged { page_size: 8, total_pages: None, sharing: true }
}

/// System allocator with an allocation-event counter (frees are not
/// counted — only acquiring fresh memory violates the budget).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn cfg_for(spec: &str) -> HostCfg {
    HostCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 48,
        seq_len: 32,
        policy: QuantPolicy::resolve(spec).unwrap(),
        rope_theta: 10000.0,
    }
}

/// Decode `steps` tokens through `forward_token_into` and return how many
/// allocation events the loop performed.
fn allocs_during_decode(spec: &str, store: CacheStore, layout: KvLayout, steps: usize) -> u64 {
    let cfg = cfg_for(spec);
    let params = host_test_params(&cfg, 7);
    let model = HostModel::new(cfg.clone(), &params).unwrap();
    let mut pool = model.make_pool_with(1, store, layout).unwrap();
    let slot = pool.alloc().unwrap();
    let mut scratch = DecodeScratch::for_cfg(&cfg);

    // prefill a short prompt, keeping the last logits to seed the loop
    let prompt = [1i32, 9, 33, 2];
    let mut tok = 0i32;
    for (pos, &t) in prompt.iter().enumerate() {
        let lg = model
            .forward_token_into(&mut pool, slot, t, pos, true, &mut scratch)
            .unwrap()
            .unwrap();
        tok = silq::evalharness::decode::argmax(lg) as i32;
    }

    let before = ALLOCS.load(Ordering::Relaxed);
    let mut pos = prompt.len();
    for _ in 0..steps {
        let lg = model
            .forward_token_into(&mut pool, slot, tok, pos, true, &mut scratch)
            .unwrap()
            .unwrap();
        tok = silq::evalharness::decode::argmax(lg) as i32;
        pos += 1;
    }
    ALLOCS.load(Ordering::Relaxed) - before
}

/// Advance `lanes` pool sessions `steps` times through the cross-lane
/// batched forward and return the allocation events of the steady-state
/// loop (the lane array and both scratches are built before counting).
fn allocs_during_batched_decode(
    spec: &str,
    store: CacheStore,
    layout: KvLayout,
    lanes: usize,
    steps: usize,
) -> u64 {
    use silq::hostmodel::BatchLane;
    use silq::kernels::BatchScratch;
    let cfg = cfg_for(spec);
    let params = host_test_params(&cfg, 11);
    let model = HostModel::new(cfg.clone(), &params).unwrap();
    let mut pool = model.make_pool_with(lanes, store, layout).unwrap();
    let mut scratch = DecodeScratch::for_cfg(&cfg);
    let mut bscratch = BatchScratch::for_cfg(&cfg, lanes);

    // ragged prefixes: lane l prefill length 1 + l
    let mut lane_state: Vec<BatchLane> = (0..lanes)
        .map(|l| {
            let slot = pool.alloc().unwrap();
            for pos in 0..l {
                model
                    .forward_token_into(&mut pool, slot, (1 + pos) as i32, pos, false, &mut scratch)
                    .unwrap();
            }
            BatchLane { slot, tok: (1 + l) as i32, pos: l }
        })
        .collect();

    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..steps {
        let lg = model
            .forward_tokens_batch(&mut pool, &lane_state, true, &mut bscratch)
            .unwrap()
            .unwrap();
        let v = cfg.vocab;
        for (l, ln) in lane_state.iter_mut().enumerate() {
            ln.tok = silq::evalharness::decode::argmax(&lg[l * v..(l + 1) * v]) as i32;
            ln.pos += 1;
        }
    }
    ALLOCS.load(Ordering::Relaxed) - before
}

/// One test on purpose: the counter is global, so the instrument check and
/// the measured decode loops must never run on sibling test threads.
#[test]
fn steady_state_decode_allocates_nothing() {
    // first prove the instrument counts at all — otherwise a broken hook
    // would green-light everything below
    let before = ALLOCS.load(Ordering::Relaxed);
    let v: Vec<u8> = Vec::with_capacity(4096);
    std::hint::black_box(&v);
    drop(v);
    assert!(ALLOCS.load(Ordering::Relaxed) > before, "allocation counter is not wired up");

    // telemetry ON for the whole measurement: counters, spans and the
    // trace ring must all record allocation-free in steady state (the ring
    // is reserved here, before any counted window)
    silq::obs::enable_tracing(1 << 16);
    let gemv_before = silq::obs::get(silq::obs::Counter::GemvCalls);
    let attend_before = silq::obs::get(silq::obs::Counter::AttendI8Calls);

    // every path through forward_token_into: integer kernels over the int8
    // slab, quantized fallback over the f32 store, static-act steps, and
    // the unquantized fp16 path
    for (spec, store) in [
        ("w4a8kv8", CacheStore::Int8),
        ("w4a8kv8", CacheStore::F32),
        ("w4a8kv8:statacts", CacheStore::Int8),
        ("fp16", CacheStore::F32),
    ] {
        for layout in [KvLayout::Slab, paged()] {
            let n = allocs_during_decode(spec, store, layout, 20);
            assert_eq!(
                n, 0,
                "{spec}/{store:?}/{layout:?}: steady-state forward_token_into \
                 performed {n} heap allocations"
            );
        }
    }

    // the cross-lane batched step inherits the budget: one fused forward
    // across 3 ragged lanes, zero allocations in steady state — on the
    // paged pool the 20-step window crosses page boundaries, so the lazy
    // page binds themselves must also be allocation-free (page tables are
    // pre-sized to their slot's maximum)
    for (spec, store) in [
        ("w4a8kv8", CacheStore::Int8),
        ("w4a8kv8:statacts", CacheStore::Int8),
        ("fp16", CacheStore::F32),
    ] {
        for layout in [KvLayout::Slab, paged()] {
            let n = allocs_during_batched_decode(spec, store, layout, 3, 20);
            assert_eq!(
                n, 0,
                "{spec}/{store:?}/{layout:?}: steady-state forward_tokens_batch \
                 performed {n} heap allocations"
            );
        }
    }

    // the same sweeps with the worker pool active: thread spawn and the
    // lazy per-worker state are paid inside configure() (it runs a warm-up
    // job), before any counted window, so steady-state sharded decode must
    // stay allocation-free too — the pool's publish path is a mutex +
    // atomics + park/unpark, no heap. The counting allocator is global
    // across threads, so worker-side allocations would be caught here.
    silq::kernels::pool::configure(4);
    for (spec, store) in [("w4a8kv8", CacheStore::Int8), ("fp16", CacheStore::F32)] {
        for layout in [KvLayout::Slab, paged()] {
            let n = allocs_during_decode(spec, store, layout, 20);
            assert_eq!(
                n, 0,
                "{spec}/{store:?}/{layout:?}: pooled forward_token_into \
                 performed {n} heap allocations"
            );
            let n = allocs_during_batched_decode(spec, store, layout, 3, 20);
            assert_eq!(
                n, 0,
                "{spec}/{store:?}/{layout:?}: pooled forward_tokens_batch \
                 performed {n} heap allocations"
            );
        }
    }
    silq::kernels::pool::shutdown();

    // the zero-alloc loops above ran with telemetry live — prove the
    // instrumentation actually recorded (a disabled hook passing the pin
    // would be vacuous) and that every span closed
    assert!(
        silq::obs::get(silq::obs::Counter::GemvCalls) > gemv_before,
        "integer decode recorded no GEMV calls with telemetry enabled"
    );
    assert!(
        silq::obs::get(silq::obs::Counter::AttendI8Calls) > attend_before,
        "integer decode recorded no int8 attention calls with telemetry enabled"
    );
    assert_eq!(
        silq::obs::get(silq::obs::Counter::SpanEnter),
        silq::obs::get(silq::obs::Counter::SpanExit),
        "unbalanced telemetry spans"
    );
    assert!(!silq::obs::events().is_empty(), "tracing recorded no span events");
}
