//! End-to-end telemetry integration: one serve run with tracing live,
//! asserting the acceptance contract of the obs PR — the counter registry
//! totals exactly match the `ServeStats` accounting, the `--metrics-out`
//! per-step series sums to the aggregates, and the Chrome-trace export is
//! well-formed trace_event JSON.
//!
//! Single-test binary on purpose: the counter registry is process-global,
//! so exact-delta assertions are only sound when nothing else records
//! concurrently (the lib unit tests stay tolerant for the same reason).

use silq::hostmodel::host_test_params;
use silq::obs::{self, Counter};
use silq::serve::{serve_inline, CacheStore, GenRequest, HostBackend, HostCfg};

fn cfg() -> HostCfg {
    HostCfg {
        vocab: 256,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 32,
        policy: "w4a8kv8".parse().unwrap(),
        rope_theta: 10000.0,
    }
}

/// Count occurrences of `needle` in `hay` (step-row counting in the
/// metrics document without a JSON parser).
fn occurrences(hay: &str, needle: &str) -> usize {
    hay.match_indices(needle).count()
}

#[test]
fn serve_run_exports_consistent_trace_and_metrics() {
    obs::enable_tracing(1 << 14);
    let c0: Vec<u64> = Counter::ALL.iter().map(|&c| obs::get(c)).collect();
    let delta = |c: Counter| obs::get(c) - c0[c as usize];

    let cfg = cfg();
    let params = host_test_params(&cfg, 17);
    let backend = HostBackend::new(cfg, 4, &params, CacheStore::Int8).unwrap();
    let n_requests = 24u64;
    let reqs: Vec<GenRequest> = (0..n_requests)
        .map(|i| {
            let prompt: Vec<i32> = (0..3 + (i % 3) as i32).map(|p| 1 + (i as i32 * 7 + p) % 250).collect();
            GenRequest::new(i, prompt, 2 + (i as usize % 5)).ignore_eos()
        })
        .collect();
    let (results, stats) = serve_inline(backend, 4, reqs).unwrap();
    assert_eq!(results.len(), n_requests as usize);
    assert_eq!(stats.completed, n_requests as usize);

    // --- counter registry vs ServeStats: exact accounting ---
    assert_eq!(delta(Counter::ServeEnqueued), n_requests);
    assert_eq!(delta(Counter::ServeAdmitted), n_requests);
    assert_eq!(delta(Counter::ServeCompleted), stats.completed as u64);
    assert_eq!(delta(Counter::ServeRejected), stats.rejected as u64);
    assert_eq!(delta(Counter::ServeEvicted), stats.completed as u64);
    assert_eq!(delta(Counter::ServeSteps), stats.steps);
    assert_eq!(delta(Counter::ServeNewTokens), stats.total_new_tokens as u64);
    // the integer decode actually went through the instrumented kernels
    assert!(delta(Counter::GemvCalls) + delta(Counter::GemmCalls) > 0);
    assert!(delta(Counter::AttendI8Calls) > 0);
    assert!(delta(Counter::KvBytesRead) > 0);
    assert_eq!(obs::get(Counter::SpanEnter), obs::get(Counter::SpanExit), "unbalanced spans");

    // --- TTFT accounting: stamping at the first emitted token (streaming
    // rework) must be bit-equal to the per-result values — same Instant,
    // same ms conversion, summed in the same µs units the histogram keeps
    assert_eq!(stats.ttft.count(), results.iter().filter(|r| r.ttft_ms.is_finite()).count() as u64);
    assert_eq!(
        stats.ttft.sum_us(),
        results
            .iter()
            .filter(|r| r.ttft_ms.is_finite())
            .map(|r| (r.ttft_ms * 1e3) as u64)
            .sum::<u64>(),
        "first-token TTFT stamps diverged from the result latencies"
    );

    // --- per-step series: one row per step, sums match the aggregates ---
    assert_eq!(stats.series.len() as u64, stats.steps);
    assert_eq!(
        stats.series.iter().map(|r| r.new_tokens).sum::<usize>(),
        stats.total_new_tokens
    );
    assert_eq!(stats.series.iter().map(|r| r.kv_bytes).max().unwrap_or(0), stats.kv_bytes_peak);

    // --- metrics JSON: schema + totals literally match the stats ---
    let doc = stats.metrics_json();
    assert!(doc.starts_with('{') && doc.ends_with('}'));
    assert!(doc.contains("\"schema\":\"silq.metrics.v1\""));
    assert_eq!(occurrences(&doc, "\"step\":"), stats.steps as usize, "one series row per step");
    for needle in [
        format!("\"steps\":{}", stats.steps),
        format!("\"completed\":{}", stats.completed),
        format!("\"rejected\":{}", stats.rejected),
        format!("\"new_tokens\":{}", stats.total_new_tokens),
        format!("\"kv_bytes_peak\":{}", stats.kv_bytes_peak),
    ] {
        assert!(doc.contains(&needle), "metrics JSON missing `{needle}`:\n{doc}");
    }
    assert!(!doc.contains("NaN") && !doc.contains("inf"), "non-JSON numbers leaked:\n{doc}");

    // --- Chrome trace: well-formed, complete events on lane tracks ---
    let trace = obs::export::chrome_trace_json();
    assert!(trace.starts_with('{') && trace.ends_with('}'));
    assert!(trace.contains("\"traceEvents\":["));
    assert!(trace.contains("\"ph\":\"X\""));
    assert!(trace.contains("\"name\":\"step\""), "missing scheduler step spans");
    assert!(trace.contains("\"name\":\"request\""), "missing request lifecycle events");
    assert!(trace.contains("\"name\":\"prefill\""), "missing prefill spans");
    assert!(trace.contains("\"cat\":\"hostmodel\""), "missing hostmodel phase spans");
    assert!(trace.contains("\"counters\":{") && trace.contains("\"serve_steps\":"));

    // --- both writers land on disk and round-trip ---
    let dir = std::env::temp_dir();
    let trace_path = dir.join(format!("silq_obs_{}.trace.json", std::process::id()));
    let metrics_path = dir.join(format!("silq_obs_{}.metrics.json", std::process::id()));
    obs::export::write_chrome_trace(trace_path.to_str().unwrap()).unwrap();
    std::fs::write(&metrics_path, &doc).unwrap();
    let trace_back = std::fs::read_to_string(&trace_path).unwrap();
    assert!(trace_back.contains("\"traceEvents\":["));
    assert_eq!(std::fs::read_to_string(&metrics_path).unwrap(), doc);
    let _ = std::fs::remove_file(&trace_path);
    let _ = std::fs::remove_file(&metrics_path);
}
