//! Chaos soak: a seeded fault plan driven through the real wire server,
//! with the books balanced afterwards (`make chaos`).
//!
//! One sequential client streams a storm of requests at a live
//! [`Server`] while the deterministic fault plan fires: KV-pool allocs
//! fail, kernel shards stall, streamed frames tear mid-write, the
//! admission queue reports full, and one request slowlorises its own
//! body. Because the client is sequential and every trigger is an exact
//! hit count (see [`silq::faults`]), the same plan + seed produces the
//! same storm every run — the chaos is replayable.
//!
//! What must hold when the dust settles:
//!
//! * **Exact books**: `ServeStats` == the obs counter deltas == what the
//!   client observed on the wire, for every terminal class (completed /
//!   rejected / cancelled / deadline-shed / deadline-evicted / 429 /
//!   guard-408), and the classes partition the admitted total exactly.
//! * **No leaks**: every KV slot is free and zero cache bytes are
//!   resident after drain, torn streams and evictions included.
//! * **Health cycle**: `/healthz` is `ok` before the storm, `degraded`
//!   (with deadline-miss evidence) right after it, `ok` again after a
//!   bounded amount of calm traffic, and the run ends `draining`.

use silq::hostmodel::host_test_params;
use silq::net::{client as netclient, Json, Server, ServerCfg};
use silq::obs::{self, Counter};
use silq::serve::{health, CacheStore, DecodeBackend, HealthState, HostBackend, HostCfg};
use silq::util::Rng;
use silq::{faults, kernels::pool};

/// The plan: triggers are chosen against the fixed storm script below so
/// forced-full submits (2, 11, 20 → ids 1, 10, 19) and KV alloc failures
/// (6th and 13th alloc → plain buffered ids) never land on a designated
/// shed/evict id — the designated counts stay exact. The `lat` period
/// (25) is shorter than any 6-token decode run's pool-call count, so at
/// least one 120 ms stall is guaranteed to land inside a *measured*
/// decode step and trip the watchdog (not only inside prefill).
const PLAN: &str = "kv@6+7,lat@10+25:120,torn@5+10,stall@24:600,full@2+9,seed=42";

const STORM: usize = 24;
const SHED_IDS: [usize; 3] = [3, 7, 22]; // ttft_deadline_ms = 0 → 503
const EVICT_IDS: [usize; 3] = [5, 13, 21]; // deadline_ms = 0 → evicted
const STREAM_IDS: [usize; 4] = [6, 9, 14, 17]; // SSE → torn-write targets
const STALL_ID: usize = 23; // last request: fault-stalled body → 408
const CALM: usize = 14; // 14 × 8 tokens = 112 healthy steps > PRESSURE_CAP

fn healthz_doc(addr: &str) -> Json {
    let (s, body) = netclient::get(addr, "/healthz").unwrap();
    assert_eq!(s, 200, "{body}");
    Json::parse(&body).unwrap()
}

fn health_status(doc: &Json) -> String {
    doc.get("status").and_then(Json::as_str).unwrap().to_string()
}

#[test]
fn seeded_fault_storm_balances_the_books_and_health_recovers() {
    obs::set_enabled(true);
    pool::configure(pool::env_threads().unwrap_or(1));
    faults::clear(); // a clean slate no matter what ran before

    let seq_len = 32;
    let lanes = 2;
    let cfg = HostCfg {
        vocab: 256,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len,
        policy: "w4a8kv8".parse().unwrap(),
        rope_theta: 10000.0,
    };
    let params = host_test_params(&cfg, 71);
    let store = CacheStore::for_policy(&cfg.policy);
    let backend = HostBackend::new(cfg, lanes, &params, store).unwrap();
    let server = Server::bind(ServerCfg {
        addr: "127.0.0.1:0".into(),
        lanes,
        queue_cap: 8,
        max_conns: 8,
        default_max_new: 4,
        header_timeout_ms: 300, // the stalled request must 408 quickly
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let worker = std::thread::spawn(move || server.run(backend).unwrap());

    // baseline counter snapshot (other suites may have run in-process)
    let d = |c: Counter, c0: u64| obs::get(c) - c0;
    let enq0 = obs::get(Counter::ServeEnqueued);
    let shed0 = obs::get(Counter::DeadlineShed);
    let evic0 = obs::get(Counter::DeadlineEvicted);
    let r4290 = obs::get(Counter::Net429);
    let r5030 = obs::get(Counter::Net503Shed);
    let guard0 = obs::get(Counter::NetGuardRejects);
    let slow0 = obs::get(Counter::WatchdogSlowSteps);
    let inj0 = obs::get(Counter::FaultsInjected);

    // before the storm: a fresh server is healthy
    assert_eq!(health_status(&healthz_doc(&addr)), "ok");

    // arm the plan; traffic derives from its seed so plan + seed fully
    // determine the run
    faults::configure(PLAN).unwrap();
    let mut rng = Rng::new(faults::seed());

    // ---- the storm: one sequential client, 24 scripted requests -------
    let mut c_ok = 0usize; // 200, reason "ok"
    let mut c_rej = 0usize; // 200, reason "rejected" (KV exhaustion)
    let mut c_evict = 0usize; // 200, reason "deadline" (mid-decode)
    let mut c_429 = 0usize;
    let mut c_503 = 0usize; // TTFT shed
    let mut c_torn = 0usize; // stream broke mid-read (torn write)
    let mut stalled_refused = false;

    for i in 0..STORM {
        let plen = 1 + rng.below(4);
        let prompt: Vec<i32> = (0..plen).map(|_| 1 + rng.below(250) as i32).collect();
        let shed = SHED_IDS.contains(&i);
        let evict = EVICT_IDS.contains(&i);
        let streamv = STREAM_IDS.contains(&i);
        let budget = if evict { 4 } else if streamv { 6 } else { 3 };
        let body = netclient::completion_body_ext(
            i as u64,
            &prompt,
            budget,
            true,
            streamv,
            Some(if evict { "batch" } else { "interactive" }),
            evict.then_some(0),
            shed.then_some(0),
        );
        if i == STALL_ID {
            // the armed `stall` fault sleeps past the server's guard
            // window mid-send; the server answers 408 and hangs up, so
            // the client sees either the 408 or a broken socket
            match netclient::complete_buffered(&addr, &body) {
                Ok(o) => {
                    assert_eq!(o.status, 408, "{:?}", o.done);
                    stalled_refused = true;
                }
                Err(_) => stalled_refused = true,
            }
            continue;
        }
        if streamv {
            match netclient::complete_streaming(&addr, &body, None) {
                Err(_) => c_torn += 1,
                Ok(o) => match o.status {
                    429 => c_429 += 1,
                    503 => c_503 += 1,
                    200 => {
                        let done = o.done.expect("stream ended without a done frame");
                        match done.get("reason").and_then(Json::as_str) {
                            Some("ok") => c_ok += 1,
                            Some("rejected") => c_rej += 1,
                            other => panic!("stream {i}: unexpected reason {other:?}"),
                        }
                    }
                    s => panic!("stream {i}: unexpected status {s}"),
                },
            }
            continue;
        }
        let o = netclient::complete_buffered(&addr, &body).unwrap();
        match o.status {
            429 => {
                assert!(o.retry_after_ms.unwrap() >= 1, "429 without a backoff hint");
                c_429 += 1;
            }
            503 => {
                let done = o.done.as_ref().expect("shed without a body");
                assert_eq!(done.get("reason").and_then(Json::as_str), Some("deadline_shed"));
                assert!(o.retry_after_ms.unwrap() >= 1, "shed without a backoff hint");
                assert!(shed, "request {i} shed without an expired TTFT deadline");
                c_503 += 1;
            }
            200 => {
                let done = o.done.as_ref().unwrap();
                match done.get("reason").and_then(Json::as_str) {
                    Some("ok") => c_ok += 1,
                    Some("rejected") => {
                        let err = done.get("error").and_then(Json::as_str).unwrap();
                        assert!(err.contains("KV pool"), "reject without KV evidence: {err}");
                        c_rej += 1;
                    }
                    Some("deadline") => {
                        assert!(evict, "request {i} evicted without a deadline");
                        assert_eq!(
                            o.tokens.len(),
                            1,
                            "eviction must land at the first step boundary"
                        );
                        c_evict += 1;
                    }
                    other => panic!("request {i}: unexpected reason {other:?}"),
                }
            }
            s => panic!("request {i}: unexpected status {s}"),
        }
    }

    // the storm's fault ledger, before clear() zeroes it
    let injected: std::collections::HashMap<&str, u64> =
        faults::report().into_iter().map(|(name, _hits, inj)| (name, inj)).collect();
    assert_eq!(injected["full"], 3, "forced-full fires on submits 2, 11, 20");
    assert_eq!(injected["stall"], 1);
    assert!(injected["kv"] >= 1, "the KV alloc fault never fired");
    assert!(injected["torn"] >= 1, "the torn-write fault never fired");
    assert!(injected["lat"] >= 1, "the shard-latency fault never fired");
    assert!(stalled_refused, "the stalled request was served anyway");

    // right after the storm (its tail is a shed): degraded, with evidence
    let hz = healthz_doc(&addr);
    assert_eq!(health_status(&hz), "degraded", "{hz:?}");
    assert!(
        hz.get("deadline_misses").and_then(Json::as_u64).unwrap() >= 6,
        "degraded without deadline-miss evidence: {hz:?}"
    );
    assert!(hz.get("pressure").and_then(Json::as_u64).unwrap() >= 1);
    assert!(
        d(Counter::WatchdogSlowSteps, slow0) >= 1,
        "120 ms shard stalls must flag slow steps"
    );

    // ---- calm: disarm, drain the pressure with healthy traffic --------
    faults::clear();
    for i in 0..CALM {
        let prompt: Vec<i32> = (0..3).map(|_| 1 + rng.below(250) as i32).collect();
        let body = netclient::completion_body((STORM + i) as u64, &prompt, 8, true, false);
        let o = netclient::complete_buffered(&addr, &body).unwrap();
        assert_eq!(o.status, 200, "calm traffic must serve cleanly");
        assert_eq!(o.tokens.len(), 8);
        c_ok += 1;
    }
    // bounded recovery: ≤ PRESSURE_CAP healthy steps drain any storm
    let hz = healthz_doc(&addr);
    assert_eq!(health_status(&hz), "ok", "health did not recover: {hz:?}");

    // ---- drain and balance the books ----------------------------------
    assert_eq!(netclient::shutdown(&addr).unwrap(), 200);
    let ((results, stats, backend), net) = worker.join().unwrap();

    // every class, three ways: client observation == ServeStats == counters
    assert_eq!((c_503, stats.deadline_shed), (3, 3), "TTFT sheds");
    assert_eq!(d(Counter::DeadlineShed, shed0), 3);
    assert_eq!((net.shed_503, d(Counter::Net503Shed, r5030)), (3, 3));
    assert_eq!((c_evict, stats.deadline_evicted), (3, 3), "deadline evictions");
    assert_eq!(d(Counter::DeadlineEvicted, evic0), 3);
    assert_eq!((c_429 as u64, net.rejected_429), (3, 3), "forced 429s");
    assert_eq!(d(Counter::Net429, r4290), 3);
    assert_eq!(net.guard_rejects, 1, "the stalled request must be guard-rejected");
    assert_eq!(d(Counter::NetGuardRejects, guard0), 1);
    assert_eq!(
        stats.rejected as u64, injected["kv"],
        "every fired KV fault must surface as exactly one typed reject"
    );
    assert_eq!(c_rej, stats.rejected, "client saw different rejects than the engine");
    assert_eq!(
        c_torn as u64, injected["torn"],
        "every torn write must break exactly one client stream"
    );
    assert_eq!(
        stats.cancelled as u64, net.disconnects,
        "every mid-stream tear cancels its lane exactly once"
    );
    assert!(stats.cancelled <= c_torn, "a tear on a terminal frame cancels nothing");

    // the classes partition everything that entered the queue: 24 storm
    // requests minus 3 forced 429s minus the stalled 408, plus the calm
    let admitted = (STORM - 3 - 1) + CALM;
    assert_eq!(d(Counter::ServeEnqueued, enq0), admitted as u64);
    assert_eq!(results.len(), admitted);
    assert_eq!(
        stats.completed
            + stats.rejected
            + stats.cancelled
            + stats.deadline_shed
            + stats.deadline_evicted,
        admitted,
        "terminal classes must partition the admitted total"
    );
    assert!(c_ok <= stats.completed, "client cannot see more completions than served");
    assert_eq!(
        d(Counter::FaultsInjected, inj0),
        injected.values().sum::<u64>(),
        "the counter and the per-site ledger disagree"
    );

    // no lane outlived its deadline, nothing leaked
    for r in &results {
        if EVICT_IDS.contains(&(r.id as usize)) {
            assert!(
                r.generated().len() <= 1,
                "request {} outlived its expired deadline ({} tokens)",
                r.id,
                r.generated().len()
            );
        }
    }
    assert!(backend.all_slots_free(), "the storm leaked a KV slot");
    assert_eq!(backend.kv_bytes(), 0, "the storm left KV bytes resident");
    assert!(backend.all_pages_free(), "the storm leaked a KV page");
    assert_eq!(health::state(), HealthState::Draining, "a drained run reports draining");
}
