//! Wire soak: deterministic id-keyed churn through the HTTP front-end
//! over real sockets — mixed streaming/buffered traffic, forced
//! mid-stream disconnects, admission rejections (out-of-vocab prompts),
//! zero-budget and window-clipped completions, and genuine queue-full
//! 429s (clients retry until accepted) — asserting that three independent
//! ledgers agree exactly at drain:
//!
//! * `ServeStats` (the scheduler's own accounting),
//! * the global telemetry counters (`obs`),
//! * the wire-side `NetReport` tallies plus what the clients observed.
//!
//! Single-test binary on purpose: the telemetry registry is process
//! global, so exact counter deltas need the process to themselves.

use std::sync::atomic::Ordering;
use std::time::Duration;

use silq::hostmodel::host_test_params;
use silq::net::{client as netclient, Json, Server, ServerCfg};
use silq::obs::Counter;
use silq::serve::{CacheStore, DecodeBackend, HostBackend, HostCfg};

fn soak_cfg() -> HostCfg {
    HostCfg {
        vocab: 256,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 24,
        policy: "w4a8kv8".parse().unwrap(),
        rope_theta: 10000.0,
    }
}

/// Malformed request: admission must reject it (never the transport).
fn is_bad(id: u64) -> bool {
    id % 17 == 3
}

/// Even ids stream token-by-token, odd ids take the buffered answer.
fn is_streaming(id: u64) -> bool {
    id % 2 == 0
}

/// Same budget classes as the in-process soak: zero-budget, window-bound,
/// and small completions.
fn budget(id: u64, seq_len: usize) -> usize {
    match id % 13 {
        0 => 0,
        1 => seq_len * 2,
        m => m as usize % 6 + 1,
    }
}

/// Streaming requests with the window-bound budget hang up after one
/// token: plenty of decode left, so the server's next frame write fails
/// and the lane must cancel mid-decode.
fn wants_disconnect(id: u64) -> bool {
    is_streaming(id) && id % 13 == 1 && !is_bad(id)
}

fn prompt(id: u64) -> Vec<i32> {
    let plen = 1 + (id % 7) as usize;
    let mut p: Vec<i32> =
        (0..plen as i32).map(|k| 1 + (id as i32 * 31 + k * 7) % 250).collect();
    if is_bad(id) {
        p.push(9999); // out of vocab: rejected at admission
    }
    p
}

#[test]
fn wire_soak_accounts_for_every_request_and_frees_everything() {
    silq::obs::set_enabled(true);
    silq::kernels::pool::configure(silq::kernels::pool::env_threads().unwrap_or(4));
    let c0: Vec<u64> = Counter::ALL.iter().map(|&c| silq::obs::get(c)).collect();
    let delta = move |c: Counter| silq::obs::get(c) - c0[c as usize];
    let w0 = silq::obs::wire_ttft().count();

    let clients_n: u64 = 6;
    let n: u64 = if cfg!(debug_assertions) { 120 } else { 360 };
    let lanes = 2;
    let cfg = soak_cfg();
    let seq_len = cfg.seq_len;
    let params = host_test_params(&cfg, 71);
    let backend = HostBackend::new(cfg, lanes, &params, CacheStore::Int8).unwrap();
    // capacity (2 lanes + 2 queue slots) deliberately below the 6
    // concurrent clients, so queue-full 429s happen for real
    let server = Server::bind(ServerCfg {
        addr: "127.0.0.1:0".into(),
        lanes,
        queue_cap: 2,
        max_conns: 8,
        default_max_new: 4,
        header_timeout_ms: 5000,
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let flag = server.shutdown_flag();
    let worker = std::thread::spawn(move || server.run(backend).unwrap());

    // churn: each client drives its id slice sequentially, retrying 429s
    // until accepted — so every request is enqueued exactly once
    let clients: Vec<_> = (0..clients_n)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || -> (u64, u64) {
                let (mut retries, mut disconnects) = (0u64, 0u64);
                for id in (0..n).filter(|id| id % clients_n == c) {
                    let body = netclient::completion_body(
                        id, &prompt(id), budget(id, seq_len), true, is_streaming(id),
                    );
                    loop {
                        let o = if is_streaming(id) {
                            let cut = if wants_disconnect(id) { Some(1) } else { None };
                            netclient::complete_streaming(&addr, &body, cut).unwrap()
                        } else {
                            netclient::complete_buffered(&addr, &body).unwrap()
                        };
                        match o.status {
                            429 => {
                                retries += 1;
                                std::thread::sleep(Duration::from_millis(1));
                            }
                            200 => {
                                if is_bad(id) {
                                    // rejected at admission: delivered as a
                                    // terminal document with the reason
                                    let done = o.done.expect("rejection lost its terminal doc");
                                    let err =
                                        done.get("error").and_then(Json::as_str).unwrap_or("");
                                    assert!(err.contains("vocab"), "request {id}: {err:?}");
                                    assert!(o.tokens.is_empty());
                                } else if wants_disconnect(id) {
                                    assert!(o.disconnected, "request {id} finished too fast");
                                    disconnects += 1;
                                } else {
                                    let plen = 1 + (id % 7) as usize;
                                    let want = match id % 13 {
                                        0 => 0,
                                        1 => seq_len - plen, // clipped at the window
                                        m => m as usize % 6 + 1,
                                    };
                                    assert_eq!(
                                        o.tokens.len(),
                                        want,
                                        "request {id}: wrong budget over the wire"
                                    );
                                }
                                break;
                            }
                            s => panic!("request {id}: unexpected status {s}"),
                        }
                    }
                }
                (retries, disconnects)
            })
        })
        .collect();
    let (mut retries, mut client_disconnects) = (0u64, 0u64);
    for t in clients {
        let (r, d) = t.join().unwrap();
        retries += r;
        client_disconnects += d;
    }

    flag.store(true, Ordering::SeqCst);
    let ((results, stats, backend), net) = worker.join().unwrap();

    // --- every request terminal exactly once, by one of three fates ---
    assert_eq!(results.len(), n as usize, "a request was lost or duplicated");
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n as usize, "duplicate request ids in the results");
    let n_bad = (0..n).filter(|&id| is_bad(id)).count();
    assert_eq!(stats.rejected, n_bad);
    assert_eq!(
        stats.completed + stats.rejected + stats.cancelled,
        n as usize,
        "completed/rejected/cancelled do not partition the requests"
    );
    // with ~5 guaranteed-cancellable disconnects the odds of zero actual
    // cancellations are negligible — a zero here means the disconnect ->
    // cancel path is broken
    assert!(stats.cancelled >= 1, "no disconnect cancelled its lane");
    let generated: usize = results.iter().map(|r| r.generated().len()).sum();
    assert_eq!(stats.total_new_tokens, generated, "token accounting diverged");

    // --- ledger 2: the telemetry counters equal the scheduler's stats ---
    assert_eq!(delta(Counter::ServeEnqueued), n, "every request enqueued exactly once");
    assert_eq!(delta(Counter::ServeCompleted), stats.completed as u64);
    assert_eq!(delta(Counter::ServeRejected), stats.rejected as u64);
    assert_eq!(delta(Counter::ServeCancelled), stats.cancelled as u64);
    assert_eq!(
        delta(Counter::ServeEvicted),
        (stats.completed + stats.cancelled) as u64,
        "one evict per lane departure, completed or cancelled"
    );
    assert_eq!(delta(Counter::ServeAdmitted), (stats.completed + stats.cancelled) as u64);
    assert_eq!(delta(Counter::ServeNewTokens), stats.total_new_tokens as u64);
    assert_eq!(
        silq::obs::get(Counter::SpanEnter),
        silq::obs::get(Counter::SpanExit),
        "unbalanced spans after the soak"
    );

    // --- ledger 3: wire tallies equal the clients' observations ---
    let n_streams = (0..n).filter(|&id| is_streaming(id)).count() as u64;
    assert_eq!(net.requests, n + retries, "one request tally per POST, retries included");
    assert_eq!(net.connections, n + retries);
    assert_eq!(net.rejected_429, retries, "server 429s != client-observed 429s");
    assert_eq!(net.streams, n_streams, "a 429'd attempt must not count as a stream");
    assert_eq!(delta(Counter::NetRequests), net.requests);
    assert_eq!(delta(Counter::NetConnections), net.connections);
    assert_eq!(delta(Counter::Net429), net.rejected_429);
    assert_eq!(delta(Counter::NetStreams), net.streams);
    assert_eq!(delta(Counter::NetDisconnects), net.disconnects);
    // every cancellation came from a detected disconnect; not every
    // hangup is detected (the terminal frame can win the race)
    assert!(net.disconnects >= stats.cancelled as u64);
    assert!(net.disconnects <= client_disconnects);
    assert!(silq::obs::wire_ttft().count() > w0, "no wire-TTFT sample recorded");

    // --- shutdown: nothing resident, nothing leaked ---
    assert!(backend.all_slots_free(), "a lane leaked its KV slot past drain");
    assert_eq!(backend.kv_bytes(), 0, "resident KV bytes after drain");
    assert!(backend.all_pages_free(), "a KV page leaked past drain");
    silq::kernels::pool::shutdown();
    assert_eq!(silq::kernels::pool::worker_count(), 0, "worker pool leaked threads");
}
