//! End-to-end training-loop integration: short runs must decrease loss at
//! fp16 (NTP) and quantized (KD) settings, and calibration must populate
//! every quantizer step.

use silq::config::TrainCfg;
use silq::data::{DataMix, SftStyle, Vocab, World};
use silq::metrics::RunLog;
use silq::runtime::Engine;
use silq::train::calibrate::{calibrate_act_steps, calibrate_weight_steps, collect_stats};
use silq::train::{init_model, quantize_store, Trainer};

fn ready() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

#[test]
fn fp16_pretraining_decreases_loss() {
    if !ready() {
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let mut params = init_model(&engine, "tiny_fp16_fwd", 1).unwrap();
    let world = World::generate(Vocab::new(256), 3);
    let mut tcfg = TrainCfg::default();
    tcfg.steps = 25;
    tcfg.ref_steps = 500;
    tcfg.kd_ratio = 0.0;
    let trainer = Trainer::new(&engine, "tiny_fp16_train", None, tcfg).unwrap();
    let mut log = RunLog::ephemeral();
    let stats = trainer.run(&mut params, &world, DataMix::Corpus, &mut log, None).unwrap();
    let first = log.losses[0].1;
    assert!(stats.final_loss < first * 0.9, "{} -> {}", first, stats.final_loss);
    assert!(stats.steps_per_sec() > 0.2);
}

#[test]
fn quantized_kd_training_decreases_loss_and_moves_steps() {
    if !ready() {
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let world = World::generate(Vocab::new(256), 3);
    let fp16 = init_model(&engine, "tiny_fp16_fwd", 2).unwrap();

    // calibrate a static-quant store
    let stats = collect_stats(&engine, "tiny_fp16_calib", &fp16, &world, 2, 0).unwrap();
    let policy = engine.manifest.prec("a8s-c8-w4").unwrap().policy().unwrap();
    let mut qs = quantize_store(&engine, "tiny_a8s-c8-w4_fwd", &fp16).unwrap();
    calibrate_act_steps(&mut qs, &policy, &stats).unwrap();
    calibrate_weight_steps(&mut qs, &policy).unwrap();
    for name in ["sa_x1", "sa_q", "sc_k", "sa_head", "sw_q", "sw_head"] {
        assert!(qs.get(name).unwrap().iter().all(|&v| v > 0.0), "{name} uncalibrated");
    }
    let sa_before = qs.get("sa_x1").unwrap().to_vec();

    let mut tcfg = TrainCfg::default();
    tcfg.base_lr = 1.2e-3;
    tcfg.steps = 40;
    tcfg.ref_steps = 500;
    // kd_ratio 0.5: with a *random* teacher the pure-KD loss already sits
    // at the teacher-entropy floor; the NTP half gives the decrease signal.
    tcfg.kd_ratio = 0.5;
    let trainer = Trainer::new(
        &engine,
        "tiny_a8s-c8-w4_train",
        Some(("tiny_fp16_fwd", fp16.clone())),
        tcfg,
    )
    .unwrap();
    let mut log = RunLog::ephemeral();
    let stats_t = trainer
        .run(&mut qs, &world, DataMix::Instruct { style: SftStyle::TuluSynth, dclm_ratio: 0.25 }, &mut log, None)
        .unwrap();
    // single-batch losses are noisy on a 20-step run: compare head/tail means
    let head: f32 = log.losses[..5].iter().map(|(_, l)| l).sum::<f32>() / 5.0;
    let tail: f32 = log.recent_loss(5);
    assert!(tail < head, "KD loss must trend down: head {head} tail {tail}");
    let _ = stats_t;
    // LSQ refinement moved the activation steps
    let sa_after = qs.get("sa_x1").unwrap();
    assert!(sa_before.iter().zip(sa_after).any(|(a, b)| (a - b).abs() > 1e-6));
}

#[test]
fn eval_hook_fires() {
    if !ready() {
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let world = World::generate(Vocab::new(256), 3);
    let mut params = init_model(&engine, "tiny_fp16_fwd", 4).unwrap();
    let mut tcfg = TrainCfg::default();
    tcfg.steps = 6;
    tcfg.eval_every = 2;
    tcfg.kd_ratio = 0.0;
    let trainer = Trainer::new(&engine, "tiny_fp16_train", None, tcfg).unwrap();
    let mut log = RunLog::ephemeral();
    let mut fired = vec![];
    {
        let mut hook = |s: usize, _: &silq::model::ParamStore| fired.push(s);
        trainer.run(&mut params, &world, DataMix::Corpus, &mut log, Some(&mut hook)).unwrap();
    }
    assert_eq!(fired, vec![2, 4, 6]);
}
