//! PTQ end-to-end: norm folding and rotations must preserve the fp function
//! exactly (checked through the compiled PJRT model), and each baseline
//! must produce a runnable quantized store.

use silq::coordinator::{Pipeline, PipelineCfg};
use silq::linalg::hadamard;
use silq::metrics::RunLog;
use silq::model::ParamStore;
use silq::ptq;
use silq::runtime::{build_inputs, literal_i32, to_f32_vec, Engine};
use silq::train::{init_model, quantize_store};

fn ready() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

fn fwd_fp16(engine: &Engine, qs: &ParamStore, tokens: &[i32]) -> Vec<f32> {
    // run the quantized store's *weights* through the fp16 artifact by
    // building an fp16 store from its shared tensors
    let m = engine.module("tiny_fp16_fwd").unwrap();
    let mut fp = ParamStore::from_spec(&m.spec);
    fp.copy_common_from(qs);
    let tok_spec = m.spec.inputs[m.spec.input_index("tokens").unwrap()].clone();
    let inputs =
        build_inputs(&m.spec, &fp, &[("tokens", literal_i32(&tok_spec.dims, tokens).unwrap())])
            .unwrap();
    to_f32_vec(&m.run(&inputs).unwrap()[0]).unwrap()
}

#[test]
fn fold_and_rotate_preserve_fp_function() {
    if !ready() {
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let mc = engine.manifest.model("tiny").unwrap().clone();
    let fp16 = init_model(&engine, "tiny_fp16_fwd", 123).unwrap();
    let mut qs = quantize_store(&engine, "tiny_a8d-c8-w4_fwd", &fp16).unwrap();

    let tokens: Vec<i32> = (0..32 * 64).map(|i| 1 + (i as i32 % 250)).collect();
    let base = fwd_fp16(&engine, &qs, &tokens);

    ptq::fold_norms(&mut qs, &mc).unwrap();
    let folded = fwd_fp16(&engine, &qs, &tokens);
    let d1 = base.iter().zip(&folded).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    assert!(d1 < 2e-3, "norm folding must preserve the function: {d1}");

    ptq::apply_rotation(&mut qs, &mc, &hadamard(mc.d_model)).unwrap();
    let rotated = fwd_fp16(&engine, &qs, &tokens);
    let d2 = base.iter().zip(&rotated).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    assert!(d2 < 5e-3, "rotation must preserve the fp function: {d2}");
}

#[test]
fn all_ptq_baselines_produce_runnable_models() {
    if !ready() {
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let cfg = PipelineCfg { eval_items: 4, ..Default::default() };
    let p = Pipeline::new(&engine, cfg).unwrap();
    let mut log = RunLog::ephemeral();
    let fp16 = init_model(&engine, "tiny_fp16_fwd", 5).unwrap();
    log.note("collecting stats");
    let stats = p.calib_stats(&fp16, 1).unwrap();
    for method in ["rtn", "smoothquant", "gptq", "spinquant"] {
        let qs = p.ptq_baseline(method, "a8d-c8-w4", &fp16, &stats).unwrap();
        // steps must be positive and weights finite
        for (name, vals) in qs.names.iter().zip(&qs.values) {
            assert!(vals.iter().all(|v| v.is_finite()), "{method}/{name} not finite");
            if name.starts_with("sw_") {
                assert!(vals.iter().all(|&v| v > 0.0), "{method}/{name} step <= 0");
            }
        }
        let r = p.eval("a8d-c8-w4", &qs, false).unwrap();
        assert!(r.per_task.len() == 20, "{method} eval incomplete");
    }
}

#[test]
fn smoothquant_reduces_act_range_on_outlier_channels() {
    if !ready() {
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let mc = engine.manifest.model("tiny").unwrap().clone();
    let fp16 = init_model(&engine, "tiny_fp16_fwd", 9).unwrap();
    let cfg = PipelineCfg { eval_items: 4, ..Default::default() };
    let p = Pipeline::new(&engine, cfg).unwrap();
    let stats = p.calib_stats(&fp16, 1).unwrap();
    let policy = engine.manifest.prec("a8d-c8-w4").unwrap().policy().unwrap();
    let mut qs = quantize_store(&engine, "tiny_a8d-c8-w4_fwd", &fp16).unwrap();
    let ln_before = qs.get("ln1").unwrap().to_vec();
    ptq::smoothquant(&mut qs, &mc, &policy, &stats, 0.5).unwrap();
    let ln_after = qs.get("ln1").unwrap().to_vec();
    assert!(ln_before.iter().zip(&ln_after).any(|(a, b)| (a - b).abs() > 1e-6),
        "smoothquant must migrate scales into the norm");
}
