//! Integration: the integer decode kernels against the f32 fake-quant
//! reference, swept over precision policies × cache stores.
//!
//! Three pinned properties (the integer-kernel PR's acceptance bar):
//! 1. integer-kernel incremental == integer-kernel batched, **bit-exact**,
//!    on the store matching the policy's deployment representation;
//! 2. greedy decode is **token-identical** between the integer path and
//!    the f32 fake-quant reference on the builtin `tiny`/`small` models;
//! 3. logits agree within 1e-4 relative between the two paths.
//!
//! Everything runs artifact-free (builtin configs + seeded params).

use silq::evalharness::decode::argmax;
use silq::forward::{decode_greedy, HostForward};
use silq::hostmodel::{builtin_model, host_test_params, CacheStore, HostCfg, HostModel};
use silq::kernels::DecodeScratch;
use silq::policy::QuantPolicy;
use silq::util::Rng;

/// Small sweep config — big enough to exercise multi-head attention and
/// distinct d_model/d_ff, small enough for debug-build test time.
fn sweep_cfg(spec: &str) -> HostCfg {
    HostCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 48,
        seq_len: 16,
        policy: QuantPolicy::resolve(spec).unwrap(),
        rope_theta: 10000.0,
    }
}

fn rel_close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
}

/// Pick up `SILQ_THREADS` so the release gate's sharded pass
/// (scripts/check.sh re-runs this suite at widths 1 and 4) exercises every
/// identity over the worker pool; the default stays serial. Idempotent, so
/// concurrent test threads configuring the same width are fine.
fn pool_from_env() {
    silq::kernels::pool::configure(silq::kernels::pool::env_threads().unwrap_or(1));
}

/// Property 1: for every policy × admissible store, the incremental
/// decode over the pool and the batched full-sequence forward agree — bit
/// exactly when the store matches the path's resident representation
/// (Int8 for quantized integer kernels, F32 for fp16), and at greedy-token
/// + 1e-4-logit granularity on the off-diagonal (a quantized model over an
/// F32 pool falls back to f32 attention while the batched path stays
/// integer).
#[test]
fn prop_incremental_matches_batched_across_policies_and_stores() {
    pool_from_env();
    let combos: &[(&str, CacheStore, bool)] = &[
        ("w4a8kv8", CacheStore::Int8, true),
        ("w4a8kv8", CacheStore::F32, false),
        ("w4a8kv8:statacts", CacheStore::Int8, true),
        ("w4a8kv8:statacts", CacheStore::F32, false),
        ("fp16", CacheStore::F32, true),
    ];
    for &(spec, store, exact) in combos {
        for seed in 0..6u64 {
            let cfg = sweep_cfg(spec);
            let params = host_test_params(&cfg, seed);
            let model = HostModel::new(cfg.clone(), &params).unwrap();
            let mut pool = model.make_pool(1, store).unwrap();
            let slot = pool.alloc().unwrap();
            let mut scratch = DecodeScratch::for_cfg(&cfg);

            let mut rng = Rng::new(seed ^ 0x51);
            let plen = rng.range(1, 8);
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();

            let batched = model.forward_seq(&prompt).unwrap();
            let v = cfg.vocab;
            for (pos, &tok) in prompt.iter().enumerate() {
                let inc = model
                    .forward_token_into(&mut pool, slot, tok, pos, true, &mut scratch)
                    .unwrap()
                    .unwrap();
                let bat = &batched[pos * v..(pos + 1) * v];
                if exact {
                    assert_eq!(
                        bat, inc,
                        "{spec} {store:?} seed {seed} pos {pos}: must be bit-exact"
                    );
                } else {
                    // greedy choices agree unless the contested logits are
                    // a genuine near-tie (the paths differ only by float
                    // rounding, so any flip must sit inside the tolerance)
                    let (gb, gi) = (argmax(bat), argmax(inc));
                    assert!(
                        gb == gi || rel_close(bat[gb], bat[gi], 1e-4),
                        "{spec} {store:?} seed {seed} pos {pos}: greedy diverged beyond a tie"
                    );
                    for (a, b) in bat.iter().zip(inc.iter()) {
                        assert!(
                            rel_close(*a, *b, 1e-4),
                            "{spec} {store:?} seed {seed} pos {pos}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }
}

/// Properties 2 + 3 on the builtin models: the integer path and the f32
/// fake-quant reference decode the same greedy tokens end to end through
/// the `ForwardBackend` driver, and their full-sequence logits track
/// within 1e-4 relative.
#[test]
fn prop_integer_path_matches_f32_reference_on_builtin_models() {
    pool_from_env();
    for (model_name, plen, gen) in [("tiny", 6usize, 5usize), ("small", 5, 4)] {
        for spec in ["w4a8kv8", "w4a8kv8:statacts"] {
            let mc = builtin_model(model_name).unwrap();
            let policy = QuantPolicy::resolve(spec).unwrap();
            let cfg = HostCfg::from_policy(&mc, &policy).unwrap();
            let params = host_test_params(&cfg, 71);

            let int_model = HostModel::new(cfg.clone(), &params).unwrap();
            assert!(int_model.integer_path(), "{model_name}/{spec} must earn the integer path");
            let ref_model = HostModel::new_reference(cfg.clone(), &params).unwrap();

            let prompt: Vec<i32> = (0..plen as i32).map(|i| 1 + (i * 37) % 200).collect();

            // (3) full-sequence logits within 1e-4 relative
            let li = int_model.forward_seq(&prompt).unwrap();
            let lr = ref_model.forward_seq(&prompt).unwrap();
            assert_eq!(li.len(), lr.len());
            for (i, (a, b)) in li.iter().zip(lr.iter()).enumerate() {
                assert!(
                    rel_close(*a, *b, 1e-4),
                    "{model_name}/{spec} logit {i}: {a} vs {b}"
                );
            }

            // (2) greedy decode token-identical through the decode driver:
            // integer path over the deployment Int8 pool, reference over
            // the fake-quant F32 pool
            let mut int_fwd = HostForward::from_model(int_model, 1, CacheStore::Int8).unwrap();
            let mut ref_fwd = HostForward::from_model(ref_model, 1, CacheStore::F32).unwrap();
            let gi = decode_greedy(&mut int_fwd, &[&prompt], gen).unwrap();
            let gr = decode_greedy(&mut ref_fwd, &[&prompt], gen).unwrap();
            assert_eq!(gi[0].len(), gen);
            assert_eq!(
                gi, gr,
                "{model_name}/{spec}: integer kernels diverged from the f32 reference"
            );
        }
    }
}

/// The reference build really is the f32 path (no packed weights), and the
/// auto build really is the integer path — guards against silently
/// benchmarking the same kernels twice.
#[test]
fn reference_and_auto_builds_take_different_paths() {
    let mc = builtin_model("tiny").unwrap();
    let cfg = HostCfg::from_policy(&mc, &QuantPolicy::w4a8kv8()).unwrap();
    let params = host_test_params(&cfg, 5);
    let int_model = HostModel::new(cfg.clone(), &params).unwrap();
    let ref_model = HostModel::new_reference(cfg, &params).unwrap();
    assert!(int_model.integer_path());
    assert!(!ref_model.integer_path());
    assert!(int_model.weight_bytes() < ref_model.weight_bytes());
}

/// A scratch travels across rows and sessions: interleaved decoding of two
/// lanes through one `HostForward` matches two independent single-lane
/// decodes (the scratch holds no cross-step state).
#[test]
fn shared_scratch_is_stateless_across_lanes() {
    pool_from_env();
    let cfg = sweep_cfg("w4a8kv8");
    let params = host_test_params(&cfg, 23);
    let prompts: [&[i32]; 2] = [&[1, 9, 33], &[2, 40, 7, 11]];

    let mut both = HostForward::new(cfg.clone(), 2, &params, CacheStore::Int8).unwrap();
    let interleaved = decode_greedy(&mut both, &prompts, 4).unwrap();

    for (r, p) in prompts.iter().enumerate() {
        let mut solo = HostForward::new(cfg.clone(), 1, &params, CacheStore::Int8).unwrap();
        let alone = decode_greedy(&mut solo, &[*p], 4).unwrap();
        assert_eq!(alone[0], interleaved[r], "lane {r} depends on scratch history");
    }
}
