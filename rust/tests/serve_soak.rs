//! Serve soak: a deterministic seeded load-generation run — hundreds of
//! requests through the multi-threaded continuous-batching engine with
//! forced admission rejections (out-of-vocab prompts), zero-budget
//! completions, and context-window evictions — asserting the shutdown
//! invariants that only show up under churn:
//!
//! * the `KvPool` is **fully freed** at shutdown (no lane leaks a slot,
//!   no slot is double-admitted — the pool's free-list hard errors catch
//!   the latter mid-run);
//! * every submitted request comes back exactly once, completed or
//!   rejected, never both and never lost;
//! * `ServeStats` accounting is exact: `total_new_tokens` equals the sum
//!   of per-request generated lengths, and every reported gauge is
//!   finite (no NaNs from degenerate samples).
//!
//! The default run is sized to stay cheap in debug builds; the release
//! gate (`scripts/check.sh`) runs a larger sweep, and `make soak` runs
//! the long-seed version (`SILQ_SOAK=long`) without gating tier-1.

use std::sync::Arc;

use silq::hostmodel::host_test_params;
use silq::serve::{
    AdmissionQueue, CacheStore, DecodeBackend, GenRequest, HostBackend, HostCfg, Scheduler,
    ServeStats,
};

fn soak_cfg() -> HostCfg {
    HostCfg {
        vocab: 256,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 24,
        policy: "w4a8kv8".parse().unwrap(),
        rope_theta: 10000.0,
    }
}

/// Whether request `id` is intentionally malformed (admission must reject
/// it without disturbing the run).
fn is_bad(id: u64) -> bool {
    id % 17 == 3
}

/// Deterministic request stream: the id alone decides prompt, budget, and
/// malformedness, so every soak run over the same id range generates the
/// same load regardless of producer interleaving.
fn request(id: u64, seq_len: usize) -> GenRequest {
    let plen = 1 + (id % 7) as usize;
    let mut prompt: Vec<i32> =
        (0..plen as i32).map(|p| 1 + (id as i32 * 31 + p * 7) % 250).collect();
    if is_bad(id) {
        prompt.push(9999); // out of vocab: rejected at admission
    }
    let budget = match id % 13 {
        0 => 0,           // zero-budget: completes without a decode step
        1 => seq_len * 2, // window-bounded: forced eviction at the context window
        m => m as usize % 6 + 1,
    };
    GenRequest::new(id, prompt, budget).ignore_eos()
}

#[test]
fn soak_frees_every_slot_and_keeps_stats_exact() {
    // telemetry live for the whole run — this binary is single-test, so
    // the global counters can be asserted exactly against ServeStats
    silq::obs::set_enabled(true);
    // the soak runs with the worker pool live ($SILQ_THREADS, default 4):
    // decode sharding must survive hundreds of admissions/evictions, and
    // shutdown must leave no workers behind (asserted at the end)
    silq::kernels::pool::configure(silq::kernels::pool::env_threads().unwrap_or(4));
    let c0: Vec<u64> = silq::obs::Counter::ALL.iter().map(|&c| silq::obs::get(c)).collect();
    let delta = move |c: silq::obs::Counter| silq::obs::get(c) - c0[c as usize];
    // SILQ_SOAK=long (make soak) runs the long seed; the default stays
    // cheap enough for the debug tier-1 run, and scripts/check.sh repeats
    // the suite in release where the full-size run is fast
    let long = std::env::var("SILQ_SOAK").map(|v| v == "long").unwrap_or(false);
    let n_requests: u64 = if long {
        2400
    } else if cfg!(debug_assertions) {
        160
    } else {
        480
    };
    let producers_n: u64 = 4;
    let lanes = 4;
    let cfg = soak_cfg();
    let seq_len = cfg.seq_len;
    let params = host_test_params(&cfg, 71);
    let backend = HostBackend::new(cfg, lanes, &params, CacheStore::Int8).unwrap();

    // multi-threaded producers over a deliberately small queue, so the
    // scheduler drains against real backpressure while lanes churn
    let queue = Arc::new(AdmissionQueue::new(8));
    let producers: Vec<_> = (0..producers_n)
        .map(|p| {
            let q = queue.clone();
            let n = n_requests / producers_n;
            std::thread::spawn(move || {
                for i in 0..n {
                    q.submit(request(p * n + i, seq_len)).unwrap();
                }
            })
        })
        .collect();
    let closer = {
        let q = queue.clone();
        std::thread::spawn(move || {
            for t in producers {
                t.join().unwrap();
            }
            q.close();
        })
    };

    let mut sched = Scheduler::new(backend, lanes).unwrap();
    let mut stats = ServeStats::new(lanes);
    let results = sched.run(&queue, &mut stats).unwrap();
    closer.join().unwrap();

    // --- no request lost, duplicated, or both completed and rejected ---
    assert_eq!(results.len(), n_requests as usize, "a request was lost or duplicated");
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n_requests as usize, "duplicate request ids in the results");

    let n_bad = (0..n_requests).filter(|&id| is_bad(id)).count();
    for r in &results {
        if is_bad(r.id) {
            let Some(err) = r.error.as_deref() else {
                panic!("malformed request {} was not rejected", r.id);
            };
            assert!(err.contains("vocab"), "request {}: unexpected rejection: {err}", r.id);
            assert!(r.generated().is_empty());
        } else {
            assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
            let want = match r.id % 13 {
                0 => 0,
                1 => seq_len - r.prompt_len, // clipped at the window
                m => m as usize % 6 + 1,
            };
            assert_eq!(r.generated().len(), want, "request {}: wrong budget accounting", r.id);
        }
    }

    // --- stats invariants ---
    assert_eq!(stats.rejected, n_bad);
    assert_eq!(stats.completed + stats.rejected, n_requests as usize);
    let generated_sum: usize = results.iter().map(|r| r.generated().len()).sum();
    assert_eq!(
        stats.total_new_tokens, generated_sum,
        "total_new_tokens diverged from the per-request generated lengths"
    );
    assert!(stats.steps > 0);
    assert!(stats.tokens_per_sec().is_finite() && stats.tokens_per_sec() > 0.0);
    assert!(stats.ttft_mean_ms().is_finite() && stats.ttft_mean_ms() >= 0.0);
    assert!(stats.ttft_p95_ms().is_finite() && stats.ttft_p95_ms() >= 0.0);
    assert!(stats.batch_occupancy() > 0.0 && stats.batch_occupancy() <= 1.0);
    assert!(!stats.report().contains("NaN"), "soak report leaked a NaN");

    // --- telemetry: counter totals match the exact stats accounting ---
    use silq::obs::Counter;
    assert_eq!(delta(Counter::ServeEnqueued), n_requests, "every submit counts once");
    assert_eq!(delta(Counter::ServeSteps), stats.steps, "step counter diverged from stats");
    assert_eq!(delta(Counter::ServeCompleted), stats.completed as u64);
    assert_eq!(delta(Counter::ServeRejected), stats.rejected as u64);
    assert_eq!(delta(Counter::ServeEvicted), stats.completed as u64, "one evict per completion");
    assert_eq!(
        delta(Counter::ServeNewTokens),
        stats.total_new_tokens as u64,
        "token counter diverged from stats"
    );
    // admissions = completions (rejects never admit; zero-budget admits
    // complete immediately)
    assert_eq!(delta(Counter::ServeAdmitted), stats.completed as u64);
    // spans balance under churn: every enter has its exit by shutdown
    assert_eq!(
        silq::obs::get(Counter::SpanEnter),
        silq::obs::get(Counter::SpanExit),
        "unbalanced spans after the soak"
    );
    // the per-step series mirrors the counters row for row
    assert_eq!(stats.series.len() as u64, stats.steps);
    assert_eq!(
        stats.series.iter().map(|r| r.new_tokens).sum::<usize>(),
        stats.total_new_tokens,
        "series token sum diverged from the aggregate"
    );

    // --- shutdown: the KV pool is fully freed, nothing resident ---
    assert!(
        sched.backend().all_slots_free(),
        "a lane leaked its KV slot past shutdown"
    );
    assert_eq!(sched.backend().kv_bytes(), 0, "resident KV bytes after shutdown");

    // --- worker pool: clean shutdown, no leaked worker threads ---
    silq::kernels::pool::shutdown();
    assert_eq!(
        silq::kernels::pool::worker_count(),
        0,
        "worker pool leaked threads past shutdown"
    );
    assert_eq!(silq::kernels::pool::active_threads(), 1, "pool did not return to serial");
}
