//! Serve soak: a deterministic seeded load-generation run — hundreds of
//! requests through the multi-threaded continuous-batching engine with
//! forced admission rejections (out-of-vocab prompts), zero-budget
//! completions, and context-window evictions — asserting the shutdown
//! invariants that only show up under churn:
//!
//! * the `KvPool` is **fully freed** at shutdown (no lane leaks a slot,
//!   no slot is double-admitted — the pool's free-list hard errors catch
//!   the latter mid-run);
//! * every submitted request comes back exactly once, completed or
//!   rejected, never both and never lost;
//! * `ServeStats` accounting is exact: `total_new_tokens` equals the sum
//!   of per-request generated lengths, and every reported gauge is
//!   finite (no NaNs from degenerate samples).
//!
//! The default run is sized to stay cheap in debug builds; the release
//! gate (`scripts/check.sh`) runs a larger sweep, and `make soak` runs
//! the long-seed version (`SILQ_SOAK=long`) without gating tier-1.
//!
//! The second test in this binary is the **paged-pool torture run**: a
//! deliberately page-starved paged backend (fewer physical pages than
//! two sessions' worst-case growth) churned with mixed prompt lengths, a
//! shared system prefix, and forced `kv@N` allocation faults — pinning
//! that exhaustion surfaces as typed rejects (never a panic) and that
//! the page ledger balances exactly at shutdown.

use std::sync::{Arc, Mutex};

use silq::faults;
use silq::hostmodel::{host_test_params, KvLayout};
use silq::serve::{
    AdmissionQueue, CacheStore, DecodeBackend, FinishReason, GenRequest, HostBackend, HostCfg,
    Scheduler, ServeStats,
};

/// Both tests in this binary read process-global state (obs counters,
/// the fault registry) and assert exact deltas, so they must never run
/// on sibling test threads.
static SERIAL: Mutex<()> = Mutex::new(());

fn soak_cfg() -> HostCfg {
    HostCfg {
        vocab: 256,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 24,
        policy: "w4a8kv8".parse().unwrap(),
        rope_theta: 10000.0,
    }
}

/// Whether request `id` is intentionally malformed (admission must reject
/// it without disturbing the run).
fn is_bad(id: u64) -> bool {
    id % 17 == 3
}

/// Deterministic request stream: the id alone decides prompt, budget, and
/// malformedness, so every soak run over the same id range generates the
/// same load regardless of producer interleaving.
fn request(id: u64, seq_len: usize) -> GenRequest {
    let plen = 1 + (id % 7) as usize;
    let mut prompt: Vec<i32> =
        (0..plen as i32).map(|p| 1 + (id as i32 * 31 + p * 7) % 250).collect();
    if is_bad(id) {
        prompt.push(9999); // out of vocab: rejected at admission
    }
    let budget = match id % 13 {
        0 => 0,           // zero-budget: completes without a decode step
        1 => seq_len * 2, // window-bounded: forced eviction at the context window
        m => m as usize % 6 + 1,
    };
    GenRequest::new(id, prompt, budget).ignore_eos()
}

#[test]
fn soak_frees_every_slot_and_keeps_stats_exact() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faults::clear(); // the torture test arms a kv plan; never inherit it
    // telemetry live for the whole run — the serial lock above keeps the
    // global counters exact against ServeStats
    silq::obs::set_enabled(true);
    // the soak runs with the worker pool live ($SILQ_THREADS, default 4):
    // decode sharding must survive hundreds of admissions/evictions, and
    // shutdown must leave no workers behind (asserted at the end)
    silq::kernels::pool::configure(silq::kernels::pool::env_threads().unwrap_or(4));
    let c0: Vec<u64> = silq::obs::Counter::ALL.iter().map(|&c| silq::obs::get(c)).collect();
    let delta = move |c: silq::obs::Counter| silq::obs::get(c) - c0[c as usize];
    // SILQ_SOAK=long (make soak) runs the long seed; the default stays
    // cheap enough for the debug tier-1 run, and scripts/check.sh repeats
    // the suite in release where the full-size run is fast
    let long = std::env::var("SILQ_SOAK").map(|v| v == "long").unwrap_or(false);
    let n_requests: u64 = if long {
        2400
    } else if cfg!(debug_assertions) {
        160
    } else {
        480
    };
    let producers_n: u64 = 4;
    let lanes = 4;
    let cfg = soak_cfg();
    let seq_len = cfg.seq_len;
    let params = host_test_params(&cfg, 71);
    let backend = HostBackend::new(cfg, lanes, &params, CacheStore::Int8).unwrap();

    // multi-threaded producers over a deliberately small queue, so the
    // scheduler drains against real backpressure while lanes churn
    let queue = Arc::new(AdmissionQueue::new(8));
    let producers: Vec<_> = (0..producers_n)
        .map(|p| {
            let q = queue.clone();
            let n = n_requests / producers_n;
            std::thread::spawn(move || {
                for i in 0..n {
                    q.submit(request(p * n + i, seq_len)).unwrap();
                }
            })
        })
        .collect();
    let closer = {
        let q = queue.clone();
        std::thread::spawn(move || {
            for t in producers {
                t.join().unwrap();
            }
            q.close();
        })
    };

    let mut sched = Scheduler::new(backend, lanes).unwrap();
    let mut stats = ServeStats::new(lanes);
    let results = sched.run(&queue, &mut stats).unwrap();
    closer.join().unwrap();

    // --- no request lost, duplicated, or both completed and rejected ---
    assert_eq!(results.len(), n_requests as usize, "a request was lost or duplicated");
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n_requests as usize, "duplicate request ids in the results");

    let n_bad = (0..n_requests).filter(|&id| is_bad(id)).count();
    for r in &results {
        if is_bad(r.id) {
            let Some(err) = r.error.as_deref() else {
                panic!("malformed request {} was not rejected", r.id);
            };
            assert!(err.contains("vocab"), "request {}: unexpected rejection: {err}", r.id);
            assert!(r.generated().is_empty());
        } else {
            assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
            let want = match r.id % 13 {
                0 => 0,
                1 => seq_len - r.prompt_len, // clipped at the window
                m => m as usize % 6 + 1,
            };
            assert_eq!(r.generated().len(), want, "request {}: wrong budget accounting", r.id);
        }
    }

    // --- stats invariants ---
    assert_eq!(stats.rejected, n_bad);
    assert_eq!(stats.completed + stats.rejected, n_requests as usize);
    let generated_sum: usize = results.iter().map(|r| r.generated().len()).sum();
    assert_eq!(
        stats.total_new_tokens, generated_sum,
        "total_new_tokens diverged from the per-request generated lengths"
    );
    assert!(stats.steps > 0);
    assert!(stats.tokens_per_sec().is_finite() && stats.tokens_per_sec() > 0.0);
    assert!(stats.ttft_mean_ms().is_finite() && stats.ttft_mean_ms() >= 0.0);
    assert!(stats.ttft_p95_ms().is_finite() && stats.ttft_p95_ms() >= 0.0);
    assert!(stats.batch_occupancy() > 0.0 && stats.batch_occupancy() <= 1.0);
    assert!(!stats.report().contains("NaN"), "soak report leaked a NaN");

    // --- telemetry: counter totals match the exact stats accounting ---
    use silq::obs::Counter;
    assert_eq!(delta(Counter::ServeEnqueued), n_requests, "every submit counts once");
    assert_eq!(delta(Counter::ServeSteps), stats.steps, "step counter diverged from stats");
    assert_eq!(delta(Counter::ServeCompleted), stats.completed as u64);
    assert_eq!(delta(Counter::ServeRejected), stats.rejected as u64);
    assert_eq!(delta(Counter::ServeEvicted), stats.completed as u64, "one evict per completion");
    assert_eq!(
        delta(Counter::ServeNewTokens),
        stats.total_new_tokens as u64,
        "token counter diverged from stats"
    );
    // admissions = completions (rejects never admit; zero-budget admits
    // complete immediately)
    assert_eq!(delta(Counter::ServeAdmitted), stats.completed as u64);
    // spans balance under churn: every enter has its exit by shutdown
    assert_eq!(
        silq::obs::get(Counter::SpanEnter),
        silq::obs::get(Counter::SpanExit),
        "unbalanced spans after the soak"
    );
    // the per-step series mirrors the counters row for row
    assert_eq!(stats.series.len() as u64, stats.steps);
    assert_eq!(
        stats.series.iter().map(|r| r.new_tokens).sum::<usize>(),
        stats.total_new_tokens,
        "series token sum diverged from the aggregate"
    );

    // --- shutdown: the KV pool is fully freed, nothing resident ---
    assert!(
        sched.backend().all_slots_free(),
        "a lane leaked its KV slot past shutdown"
    );
    assert_eq!(sched.backend().kv_bytes(), 0, "resident KV bytes after shutdown");
    assert!(sched.backend().all_pages_free(), "a KV page leaked past shutdown");

    // --- worker pool: clean shutdown, no leaked worker threads ---
    silq::kernels::pool::shutdown();
    assert_eq!(
        silq::kernels::pool::worker_count(),
        0,
        "worker pool leaked threads past shutdown"
    );
    assert_eq!(silq::kernels::pool::active_threads(), 1, "pool did not return to serial");
}

// ---------------------------------------------------------------------
// paged-pool torture
// ---------------------------------------------------------------------

/// System prompt shared by every even-id torture request: two full pages
/// at the torture geometry (`page_size = 4`), so sealed-prefix sharing
/// has real material to match against.
const SYS_PREFIX: [i32; 8] = [7, 3, 11, 5, 2, 13, 17, 19];

/// Deterministic torture stream. Even ids open with the shared system
/// prefix; every fourth even id is *exactly* the prefix — the exact-fill
/// admission whose first decode write folds the final prompt token into
/// a shared page and must COW-fork it. Odd ids are private prompts of
/// mixed lengths. Budgets keep lanes occupied across admit passes so
/// page commitments genuinely collide.
fn paged_request(id: u64) -> GenRequest {
    let mut prompt: Vec<i32> = Vec::new();
    if id % 2 == 0 {
        prompt.extend_from_slice(&SYS_PREFIX);
        if id % 8 != 4 {
            let extra = 1 + (id % 5) as usize;
            prompt.extend((0..extra as i32).map(|p| 21 + (id as i32 * 13 + p * 3) % 229));
        }
    } else {
        let plen = 1 + (id % 5) as usize;
        prompt.extend((0..plen as i32).map(|p| 1 + (id as i32 * 37 + p * 11) % 250));
    }
    let budget = if id % 11 == 0 { 0 } else { 1 + (id % 5) as usize };
    GenRequest::new(id, prompt, budget).ignore_eos()
}

/// The paged-pool torture run: a page-starved paged backend under mixed
/// prompt lengths, a shared system prefix, and forced `kv@N` allocation
/// faults. Exhaustion must surface as typed [`FinishReason::Rejected`]
/// results (never a panic, never a lost request), and the page ledger
/// must balance exactly at shutdown — every page bound over the whole
/// run was returned.
#[test]
fn paged_torture_rejects_cleanly_and_balances_the_page_ledger() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    silq::obs::set_enabled(true);
    faults::clear();

    let lanes = 4;
    let cfg = soak_cfg(); // seq_len 24
    // page-starved geometry: 6 pages per slot (seq 24 / page 4) but only
    // 10 physical pages — two private sessions (12 committed pages)
    // cannot coexist, while a prefix-sharing pair (6 + 4) just fits, so
    // admission alternates between typed exhaustion rejects and shares
    let layout = KvLayout::Paged { page_size: 4, total_pages: Some(10), sharing: true };
    let params = host_test_params(&cfg, 29);
    let backend =
        HostBackend::new_with_layout(cfg, lanes, &params, CacheStore::Int8, layout).unwrap();

    let n_requests: u64 = if cfg!(debug_assertions) { 140 } else { 400 };
    // forced allocation failures layered on top of genuine exhaustion:
    // every 9th admission attempt from the 4th dies at the fault site
    faults::configure("kv@4+9,seed=23").unwrap();

    let queue = Arc::new(AdmissionQueue::new(8));
    let producer = {
        let q = queue.clone();
        std::thread::spawn(move || {
            for id in 0..n_requests {
                q.submit(paged_request(id)).unwrap();
            }
            q.close();
        })
    };

    let mut sched = Scheduler::new(backend, lanes).unwrap();
    let mut stats = ServeStats::new(lanes);
    let results = sched.run(&queue, &mut stats).unwrap();
    producer.join().unwrap();
    let injected_kv =
        faults::report().into_iter().find(|(name, ..)| *name == "kv").unwrap().2;
    faults::clear();

    // --- every request surfaces exactly once, typed, never a panic ----
    assert_eq!(results.len(), n_requests as usize, "a request was lost or duplicated");
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n_requests as usize, "duplicate request ids in the results");

    let (mut exhausted, mut injected_seen) = (0u64, 0u64);
    for r in &results {
        match r.reason {
            FinishReason::Completed => {
                assert!(r.error.is_none(), "request {} completed with an error", r.id);
                let want = if r.id % 11 == 0 { 0 } else { 1 + (r.id % 5) as usize };
                assert_eq!(r.generated().len(), want, "request {}: wrong budget", r.id);
            }
            FinishReason::Rejected => {
                let err = r.error.as_deref().unwrap_or_default();
                assert!(
                    err.contains("KV pool exhausted"),
                    "request {}: reject without pool evidence: {err}",
                    r.id
                );
                assert!(r.generated().is_empty(), "request {} generated after a reject", r.id);
                if err.contains("out of pages") {
                    exhausted += 1;
                } else {
                    assert!(err.contains("fault injection"), "request {}: {err}", r.id);
                    injected_seen += 1;
                }
            }
            other => panic!("request {}: unexpected terminal {other:?}", r.id),
        }
    }
    assert!(exhausted >= 1, "the starved pool never rejected on pages");
    assert_eq!(
        injected_seen, injected_kv,
        "every fired kv fault must surface as exactly one typed reject"
    );
    assert_eq!(stats.completed + stats.rejected, n_requests as usize);
    assert_eq!(stats.rejected as u64, exhausted + injected_seen);

    // --- exact page-ledger balance at shutdown ------------------------
    let l = sched.backend().kv_ledger();
    assert!(l.shared >= 1, "the shared system prefix never attached");
    // (COW-fork counts depend on which sessions coexist at the instant an
    // exact-fill folds its last prompt token, so the exact-fill requests
    // here are torture input only — fork determinism is pinned by the
    // kvpool unit tests)
    assert_eq!(
        l.allocated + l.revived,
        l.released,
        "page ledger out of balance after drain: {l:?}"
    );
    assert!(
        (1..=10).contains(&stats.kv_pages_peak),
        "page occupancy peak {} outside the physical pool",
        stats.kv_pages_peak
    );
    assert!(sched.backend().all_slots_free(), "a lane leaked its KV slot");
    assert!(sched.backend().all_pages_free(), "a page leaked past shutdown");
    assert_eq!(sched.backend().kv_pages(), 0, "resident pages after drain");
    assert_eq!(sched.backend().kv_bytes(), 0, "resident KV bytes after drain");
}
