//! PRNG-driven property tests (the proptest crate is unavailable offline;
//! properties are swept over seeded random cases instead — same spirit,
//! deterministic by construction).

use silq::linalg::{rotation_decomposition, random_rotation, Mat};
use silq::quant;
use silq::util::Rng;

const CASES: u64 = 40;

/// Serializes the tests that drive hostmodel traffic while reading the
/// global obs counters or reconfiguring the global worker pool — the test
/// binary runs tests on sibling threads, and those are process-wide.
fn hostmodel_traffic_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn prop_fake_quant_idempotent() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let mut x = rng.normal_vec(257, 2.0);
        let s = rng.uniform() * 0.2 + 1e-3;
        let bits = [2, 4, 8, 16][rng.below(4)];
        quant::fake_quant(&mut x, s, bits);
        let once = x.clone();
        quant::fake_quant(&mut x, s, bits);
        assert_eq!(once, x, "seed {seed}: quantization must be idempotent");
    }
}

#[test]
fn prop_fake_quant_error_bounded_in_range() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xA);
        let x = rng.normal_vec(128, 1.0);
        let s = rng.uniform() * 0.1 + 1e-3;
        let (qn, qp) = quant::qbounds(8);
        for &v in &x {
            let q = quant::fake_quant_scalar(v, s, 8);
            if v > s * qn as f32 && v < s * qp as f32 {
                assert!((q - v).abs() <= s / 2.0 + 1e-6, "seed {seed}");
            }
            assert!(q >= s * qn as f32 - 1e-6 && q <= s * qp as f32 + 1e-6);
        }
    }
}

#[test]
fn prop_quant_monotone_nondecreasing() {
    // fake quant is a monotone function of its input
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xB);
        let s = rng.uniform() * 0.3 + 1e-3;
        let mut xs = rng.normal_vec(64, 2.0);
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let qs: Vec<f32> = xs.iter().map(|&v| quant::fake_quant_scalar(v, s, 4)).collect();
        for w in qs.windows(2) {
            assert!(w[1] >= w[0] - 1e-7, "seed {seed}");
        }
    }
}

#[test]
fn prop_mse_step_within_max_bound() {
    // the optimal step never exceeds max|w|/b (clipping everything is never
    // optimal) and is positive
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xC);
        let std = rng.uniform() + 0.01;
        let w = rng.normal_vec(512, std);
        let s = quant::weight_step_mse(&w, 4);
        let maxw = w.iter().fold(0f32, |a, &b| a.max(b.abs()));
        assert!(s > 0.0 && s <= maxw / 7.5 + 1e-3, "seed {seed}: s={s}");
    }
}

#[test]
fn prop_percentile_between_zero_and_max() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xD);
        let x = rng.normal_vec(2048, 1.0);
        let sp = quant::act_step_percentile(&x, 8, 99.99);
        let sm = quant::act_step_max(&x, 8);
        assert!(sp > 0.0 && sp <= sm + 1e-9, "seed {seed}");
    }
}

#[test]
fn prop_rotation_decomposition_sane() {
    // non_rotational <= total, parts sum to total
    for seed in 0..CASES / 2 {
        let mut rng = Rng::new(seed ^ 0xE);
        let a = Mat::from_vec(12, 12, rng.normal_vec(144, 1.0));
        let mut b = a.clone();
        for v in b.data.iter_mut() {
            *v += rng.normal() * 0.2;
        }
        let s = rotation_decomposition(&a, &b);
        assert!(s.non_rotational <= s.total + 1e-6, "seed {seed}");
        assert!((s.rotational + s.non_rotational - s.total).abs() < 1e-6);
        assert!(s.rotational >= -1e-9);
    }
}

#[test]
fn prop_random_rotations_orthogonal() {
    for seed in 0..10 {
        let mut rng = Rng::new(seed ^ 0xF);
        let n = [4usize, 8, 16, 32][rng.below(4)];
        let r = random_rotation(n, &mut rng);
        assert!(silq::linalg::rotations::orthogonality_defect(&r) < 1e-3, "seed {seed} n={n}");
    }
}

#[test]
fn prop_pack_dequant_lossless_vs_fake_quant() {
    for seed in 0..CASES / 2 {
        let mut rng = Rng::new(seed ^ 0x10);
        let cols = [4usize, 8, 16][rng.below(3)];
        let rows = rng.range(2, 32);
        let w = rng.normal_vec(rows * cols, 0.2);
        let steps: Vec<f32> = (0..cols).map(|_| rng.uniform() * 0.1 + 1e-3).collect();
        let bits = [2u32, 4, 8][rng.below(3)];
        let packed = silq::quant::pack::PackedTensor::pack(&w, cols, &steps, bits).unwrap();
        let mut fq = w.clone();
        quant::fake_quant_per_channel(&mut fq, cols, &steps, bits);
        for (a, b) in packed.dequant().iter().zip(&fq) {
            assert!((a - b).abs() < 1e-6, "seed {seed}");
        }
    }
}

#[test]
fn prop_pack_unpack_exactly_lossless_2_to_8_bits() {
    // The deployability invariant the serve KV pool relies on: packing a
    // tensor to integers and unpacking reproduces fake_quant_scalar
    // *bit-exactly* (not approximately) at every bit width the integer
    // representation covers.
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x20);
        let bits = 2 + (seed % 7) as u32; // 2..=8 inclusive
        let cols = [2usize, 4, 8, 16][rng.below(4)];
        let rows = rng.range(1, 24);
        let std = rng.uniform() + 0.05;
        let w = rng.normal_vec(rows * cols, std);
        let steps: Vec<f32> = (0..cols).map(|_| rng.uniform() * 0.2 + 1e-4).collect();
        let packed = silq::quant::pack::PackedTensor::pack(&w, cols, &steps, bits).unwrap();
        let deq = packed.dequant();
        for (i, (&got, &x)) in deq.iter().zip(&w).enumerate() {
            let want = quant::fake_quant_scalar(x, steps[i % cols], bits);
            // exact equality, not a tolerance: the integer representation
            // must reproduce the fake-quant value (±0.0 compare equal)
            assert!(
                got == want,
                "seed {seed} bits {bits}: pack/unpack must be exact ({got} vs {want})"
            );
        }
    }
}

#[test]
fn prop_host_incremental_decode_matches_batched_forward() {
    // The ISSUE-2 identity: HostModel's incremental decode (KV cache in a
    // pool, on the store matching the policy's deployment representation)
    // and its batched full-sequence forward are two independent
    // implementations of the same math, and must agree *exactly* — logits
    // bit-for-bit at every prompt position, and greedy continuations
    // token-for-token — for random prompts across quantized (dynamic +
    // static cache steps) and fp16 configs. Since the integer-kernel PR
    // both paths run the packed GEMV/GEMM + int8-slab attention for
    // quantized policies, so the pinned store is Int8 there (fp16 keeps
    // f32); tests/kernels_integration.rs sweeps the off-diagonal
    // store/policy combinations at greedy-token granularity.
    use silq::evalharness::decode::argmax;
    use silq::hostmodel::{host_test_params, CacheStore, HostCfg, HostModel};
    let _traffic = hostmodel_traffic_lock();
    // honor the gate's SILQ_THREADS pass: this identity must hold at any
    // worker-pool width (scripts/check.sh re-runs the suite at 1 and 4)
    silq::kernels::pool::configure(silq::kernels::pool::env_threads().unwrap_or(1));
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed ^ 0x30);
        let (quantized, act_dynamic) = match seed % 3 {
            0 => (true, true),
            1 => (true, false),
            _ => (false, true),
        };
        let policy = match (quantized, act_dynamic) {
            (false, _) => silq::policy::QuantPolicy::fp16(),
            (true, true) => "w4a8kv8".parse().unwrap(),
            (true, false) => "w4a8kv8:statacts".parse().unwrap(),
        };
        let cfg = HostCfg {
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 12,
            policy,
            rope_theta: 10000.0,
        };
        let params = host_test_params(&cfg, seed);
        let model = HostModel::new(cfg.clone(), &params).unwrap();
        let mut pool = model.make_pool(1, CacheStore::for_policy(&cfg.policy)).unwrap();
        let slot = pool.alloc().unwrap();

        let plen = rng.range(1, 7);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();

        // logits identical at every prompt position
        let batched = model.forward_seq(&prompt).unwrap();
        let v = cfg.vocab;
        for (pos, &tok) in prompt.iter().enumerate() {
            let inc = model.forward_token(&mut pool, slot, tok, pos, true).unwrap().unwrap();
            assert_eq!(
                &batched[pos * v..(pos + 1) * v],
                &inc[..],
                "seed {seed} q={quantized} d={act_dynamic} pos {pos}: logits diverged"
            );
        }

        // greedy continuations identical: incremental extends the live
        // cache; batched recomputes the full sequence per token
        let mut row_inc = prompt.clone();
        let mut row_bat = prompt.clone();
        for _ in 0..4 {
            let pos = row_inc.len() - 1;
            let lg = if pos < prompt.len() {
                // last prompt token was already folded in above; re-derive
                // its logits from the batched pass to keep positions aligned
                batched[pos * v..(pos + 1) * v].to_vec()
            } else {
                model.forward_token(&mut pool, slot, row_inc[pos], pos, true).unwrap().unwrap()
            };
            row_inc.push(argmax(&lg) as i32);

            let full = model.forward_seq(&row_bat).unwrap();
            let last = &full[(row_bat.len() - 1) * v..row_bat.len() * v];
            row_bat.push(argmax(last) as i32);
            assert_eq!(row_inc, row_bat, "seed {seed}: greedy continuation diverged");
        }
    }
}

#[test]
fn prop_batched_cross_lane_decode_matches_sequential() {
    // The PR-5 tentpole identity, swept through the REAL continuous-
    // batching scheduler: a serve run whose every step is one fused
    // cross-lane batched forward (`HostBackend::new`) must produce
    // *token-exact* output against the per-lane sequential reference
    // (`HostBackend::new_sequential`) — random lane counts, more requests
    // than lanes (so admissions stagger and lanes sit at ragged
    // positions), random prompt lengths and budgets (some spilling past
    // the context window to force window evictions), across the w4/w8
    // integer policies and the fp16 fallback. Exactness is by
    // construction — the blocked GEMM's i32 accumulation is exact, so
    // fusing lanes cannot change any lane's row — and this sweep is the
    // end-to-end statement of it. Case count drops in debug builds; the
    // release gate in scripts/check.sh runs the full sweep.
    use silq::hostmodel::{host_test_params, CacheStore, HostCfg};
    use silq::serve::{serve_inline, GenRequest, HostBackend};
    let _traffic = hostmodel_traffic_lock();
    silq::kernels::pool::configure(silq::kernels::pool::env_threads().unwrap_or(1));
    let cases = if cfg!(debug_assertions) { 9 } else { 24 };
    for seed in 0..cases {
        let mut rng = Rng::new(seed ^ 0x51);
        let spec = ["w4a8kv8", "w8a8kv8", "fp16"][(seed % 3) as usize];
        let lanes = rng.range(1, 5);
        let cfg = HostCfg {
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 16,
            policy: spec.parse().unwrap(),
            rope_theta: 10000.0,
        };
        let params = host_test_params(&cfg, seed);
        let store = CacheStore::for_policy(&cfg.policy);
        let n_req = rng.range(lanes + 1, 3 * lanes + 6);
        let reqs: Vec<(Vec<i32>, usize)> = (0..n_req)
            .map(|_| {
                let plen = rng.range(1, 10);
                let prompt = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
                (prompt, rng.range(1, 12))
            })
            .collect();
        let mk = |reqs: &[(Vec<i32>, usize)]| -> Vec<GenRequest> {
            reqs.iter()
                .enumerate()
                .map(|(i, (p, b))| GenRequest::new(i as u64, p.clone(), *b).ignore_eos())
                .collect()
        };
        let bat = HostBackend::new(cfg.clone(), lanes, &params, store).unwrap();
        let seq = HostBackend::new_sequential(cfg.clone(), lanes, &params, store).unwrap();
        let (mut rb, stats_b) = serve_inline(bat, lanes, mk(&reqs)).unwrap();
        let (mut rs, stats_s) = serve_inline(seq, lanes, mk(&reqs)).unwrap();
        rb.sort_by_key(|r| r.id);
        rs.sort_by_key(|r| r.id);
        assert_eq!(rb.len(), n_req, "seed {seed}: a request went missing");
        assert_eq!(rs.len(), n_req);
        for (a, b) in rb.iter().zip(&rs) {
            assert_eq!(a.id, b.id);
            assert!(a.error.is_none() && b.error.is_none(), "seed {seed} req {}", a.id);
            assert_eq!(
                a.tokens, b.tokens,
                "seed {seed} spec {spec} lanes {lanes} req {}: \
                 batched cross-lane decode diverged from the sequential reference",
                a.id
            );
            // identical decode paths must also schedule identically
            assert_eq!(
                (a.admitted_step, a.finished_step),
                (b.admitted_step, b.finished_step),
                "seed {seed} req {}: scheduling diverged",
                a.id
            );
        }
        assert_eq!(stats_b.total_new_tokens, stats_s.total_new_tokens, "seed {seed}");
        assert_eq!(stats_b.steps, stats_s.steps, "seed {seed}");
    }
}

#[test]
fn prop_paged_decode_matches_slab_through_the_scheduler() {
    // The paged-pool tentpole identity, swept through the REAL scheduler:
    // `--kv paged` is indirection, not math. The same ragged serve traffic
    // run over the paged pool (small pages so prompts straddle several,
    // prefix sharing on) must produce token-exact output AND identical
    // scheduling against the slab pool, across the w4/w8 integer policies
    // and the fp16 fallback. Half the prompts open with a shared system
    // prefix, so hash-matched prefix attaches and copy-on-write forks
    // actually exercise on the paged side — exactness there is by
    // construction too: quantized K/V rows are a deterministic function of
    // the causal token prefix, so an attached sealed page holds exactly
    // the bytes a fresh prefill would have written.
    use silq::hostmodel::{host_test_params, CacheStore, HostCfg, KvLayout};
    use silq::serve::{serve_inline, GenRequest, HostBackend};
    let _traffic = hostmodel_traffic_lock();
    silq::kernels::pool::configure(silq::kernels::pool::env_threads().unwrap_or(1));
    let cases = if cfg!(debug_assertions) { 9 } else { 24 };
    for seed in 0..cases {
        let mut rng = Rng::new(seed ^ 0x9A);
        let spec = ["w4a8kv8", "w8a8kv8", "fp16"][(seed % 3) as usize];
        let lanes = rng.range(1, 5);
        let cfg = HostCfg {
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 16,
            policy: spec.parse().unwrap(),
            rope_theta: 10000.0,
        };
        let params = host_test_params(&cfg, seed);
        let store = CacheStore::for_policy(&cfg.policy);
        let prefix: Vec<i32> =
            (0..rng.range(2, 7)).map(|_| rng.below(cfg.vocab) as i32).collect();
        let n_req = rng.range(lanes + 1, 3 * lanes + 6);
        let reqs: Vec<(Vec<i32>, usize)> = (0..n_req)
            .map(|_| {
                let mut p = if rng.below(2) == 0 { prefix.clone() } else { vec![] };
                let extra = rng.range(1, 6);
                p.extend((0..extra).map(|_| rng.below(cfg.vocab) as i32));
                (p, rng.range(1, 12))
            })
            .collect();
        let mk = |reqs: &[(Vec<i32>, usize)]| -> Vec<GenRequest> {
            reqs.iter()
                .enumerate()
                .map(|(i, (p, b))| GenRequest::new(i as u64, p.clone(), *b).ignore_eos())
                .collect()
        };
        let slab = HostBackend::new(cfg.clone(), lanes, &params, store).unwrap();
        let paged = HostBackend::new_with_layout(
            cfg.clone(),
            lanes,
            &params,
            store,
            KvLayout::Paged { page_size: 4, total_pages: None, sharing: true },
        )
        .unwrap();
        let (mut rs, stats_s) = serve_inline(slab, lanes, mk(&reqs)).unwrap();
        let (mut rp, stats_p) = serve_inline(paged, lanes, mk(&reqs)).unwrap();
        rs.sort_by_key(|r| r.id);
        rp.sort_by_key(|r| r.id);
        assert_eq!(rp.len(), n_req, "seed {seed}: a request went missing");
        assert_eq!(rs.len(), n_req);
        for (a, b) in rp.iter().zip(&rs) {
            assert_eq!(a.id, b.id);
            assert!(a.error.is_none() && b.error.is_none(), "seed {seed} req {}", a.id);
            assert_eq!(
                a.tokens, b.tokens,
                "seed {seed} spec {spec} lanes {lanes} req {}: \
                 paged decode diverged from the slab reference",
                a.id
            );
            assert_eq!(
                (a.admitted_step, a.finished_step),
                (b.admitted_step, b.finished_step),
                "seed {seed} req {}: scheduling diverged",
                a.id
            );
        }
        assert_eq!(stats_p.total_new_tokens, stats_s.total_new_tokens, "seed {seed}");
        assert_eq!(stats_p.steps, stats_s.steps, "seed {seed}");
        // drained paged run: the page ledger balances exactly, nothing
        // stays resident, and the occupancy gauge saw real pages
        let l = stats_p.kv_ledger;
        assert_eq!(l.allocated + l.revived, l.released, "seed {seed}: page ledger unbalanced");
        assert!(stats_p.kv_pages_peak > 0, "seed {seed}: paged run never bound a page");
    }
}

#[test]
fn prop_parallel_gemm_matches_scalar() {
    // The parallel-kernels tentpole identity: worker-pool width and dot-
    // kernel choice are pure throughput knobs. The same ragged serve
    // traffic run at threads {1, 2, 4, 7} × {scalar, simd} kernels ×
    // {w4, w8} integer policies must produce bit-identical tokens AND
    // identical totals on every thread-count-invariant obs counter
    // (kernel work is counted once at call entry, never per shard).
    // `pool_jobs`/`pool_shards` are deliberately excluded — they measure
    // the fan-out itself.
    use silq::hostmodel::{host_test_params, CacheStore, HostCfg};
    use silq::kernels::{pool, simd, QLinear};
    use silq::obs;
    use silq::serve::{serve_inline, GenRequest, HostBackend};

    let _traffic = hostmodel_traffic_lock();
    obs::set_enabled(true);

    const INVARIANT: &[&str] = &[
        "gemv_calls",
        "gemm_calls",
        "attend_i8_calls",
        "i8_macs",
        "kv_bytes_read",
        "batch_steps",
        "decode_tokens",
        "prefill_tokens",
    ];
    let invariant = || -> Vec<(&'static str, u64)> {
        obs::snapshot().into_iter().filter(|(n, _)| INVARIANT.contains(n)).collect()
    };

    for spec in ["w4a8kv8", "w8a8kv8"] {
        let cfg = HostCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ff: 48,
            seq_len: 16,
            policy: spec.parse().unwrap(),
            rope_theta: 10000.0,
        };
        let params = host_test_params(&cfg, 0xC0FFEE ^ spec.len() as u64);
        let lanes = 3;
        let mut rng = Rng::new(0x707);
        let reqs: Vec<(Vec<i32>, usize)> = (0..9)
            .map(|_| {
                let plen = rng.range(1, 10);
                ((0..plen).map(|_| rng.below(cfg.vocab) as i32).collect(), rng.range(1, 12))
            })
            .collect();
        let mk = |reqs: &[(Vec<i32>, usize)]| -> Vec<GenRequest> {
            reqs.iter()
                .enumerate()
                .map(|(i, (p, b))| GenRequest::new(i as u64, p.clone(), *b).ignore_eos())
                .collect()
        };

        // reference: serial pool, scalar dot kernel
        pool::shutdown();
        simd::set_kernel(simd::KernelChoice::Scalar);
        obs::reset();
        let be = HostBackend::new(cfg.clone(), lanes, &params, CacheStore::Int8).unwrap();
        let (mut ref_out, ref_stats) = serve_inline(be, lanes, mk(&reqs)).unwrap();
        ref_out.sort_by_key(|r| r.id);
        let ref_counters = invariant();

        for threads in [1usize, 2, 4, 7] {
            for (kname, kernel) in
                [("scalar", simd::KernelChoice::Scalar), ("simd", simd::KernelChoice::Simd)]
            {
                pool::configure(threads);
                simd::set_kernel(kernel);
                obs::reset();
                let be =
                    HostBackend::new(cfg.clone(), lanes, &params, CacheStore::Int8).unwrap();
                let (mut out, stats) = serve_inline(be, lanes, mk(&reqs)).unwrap();
                out.sort_by_key(|r| r.id);
                assert_eq!(out.len(), ref_out.len());
                for (a, b) in ref_out.iter().zip(&out) {
                    assert_eq!(a.id, b.id);
                    assert_eq!(
                        a.tokens, b.tokens,
                        "{spec} threads={threads} kernel={kname} req {}: output diverged \
                         from the serial scalar reference",
                        a.id
                    );
                }
                assert_eq!(stats.total_new_tokens, ref_stats.total_new_tokens);
                assert_eq!(
                    invariant(),
                    ref_counters,
                    "{spec} threads={threads} kernel={kname}: kernel work counters moved \
                     with the execution config"
                );
            }
        }
    }

    // the aggregate-once closed form: one gemv bumps I8Macs by exactly
    // in·out — once per call, never per shard — at any pool width
    for threads in [1usize, 4] {
        pool::configure(threads);
        let (din, dout) = (128usize, 512usize);
        let w = vec![0.25f32; din * dout];
        let steps = vec![0.25f32; dout];
        let q = QLinear::pack(&w, dout, &steps, 8);
        let xq = vec![1i8; din];
        let mut acc = vec![0i32; dout];
        let mut out = vec![0f32; dout];
        let macs0 = obs::get(obs::Counter::I8Macs);
        let calls0 = obs::get(obs::Counter::GemvCalls);
        q.gemv(&xq, 0.5, &mut acc, &mut out);
        assert_eq!(obs::get(obs::Counter::GemvCalls) - calls0, 1);
        assert_eq!(
            obs::get(obs::Counter::I8Macs) - macs0,
            (din * dout) as u64,
            "threads={threads}: I8Macs must be the per-call closed form, not per-shard"
        );
    }

    pool::shutdown();
    simd::set_kernel(simd::KernelChoice::Simd);
}

#[test]
fn prop_bundle_roundtrip_random() {
    use silq::model::{Tensor, TensorBundle};
    for seed in 0..10 {
        let mut rng = Rng::new(seed ^ 0x11);
        let mut b = TensorBundle::new();
        for i in 0..rng.range(1, 6) {
            let n = rng.range(1, 100);
            b.insert(format!("t{i}"), Tensor::f32(vec![n], rng.normal_vec(n, 1.0)));
        }
        let path = std::env::temp_dir().join(format!("silq_prop_{seed}.bin"));
        b.save(&path).unwrap();
        let c = TensorBundle::load(&path).unwrap();
        assert_eq!(b.tensors, c.tensors);
        let _ = std::fs::remove_file(path);
    }
}

#[test]
fn prop_policy_spec_display_fromstr_roundtrip() {
    // The policy API's contract: the canonical spec string (`Display`) is
    // a lossless encoding — `FromStr` inverts it exactly for every valid
    // policy, and re-rendering is idempotent.
    use silq::policy::{CalibMethod, QuantPolicy};
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed ^ 0x7011C7);
        let p = if rng.below(8) == 0 {
            QuantPolicy::fp16()
        } else {
            let w = 2 + rng.below(15) as u32; // 2..=16
            let a = 2 + rng.below(15) as u32; // 2..=16
            let kv = 2 + rng.below(7) as u32; // 2..=8
            let mut p = QuantPolicy::integer(w, a, kv);
            if rng.below(2) == 0 {
                p = p.with_static_acts();
            }
            p.head.bits = 2 + rng.below(15) as u32;
            p.query.bits = 2 + rng.below(15) as u32;
            if rng.below(4) == 0 {
                p.online_rot = true;
            }
            if rng.below(3) == 0 {
                p = p.with_act_calib(CalibMethod::Max);
            }
            if rng.below(3) == 0 {
                p = p.with_weight_calib(CalibMethod::Lsq);
            }
            p
        };
        p.validate().unwrap_or_else(|e| panic!("seed {seed}: generated invalid policy: {e}"));
        let s = p.to_string();
        let q: QuantPolicy = s.parse().unwrap_or_else(|e| panic!("seed {seed}: {s:?}: {e}"));
        assert_eq!(q, p, "seed {seed}: spec {s:?} must round-trip exactly");
        assert_eq!(q.to_string(), s, "seed {seed}: re-rendering must be idempotent");
    }
}

#[test]
fn prop_priority_queue_never_inverts() {
    // The admission queue's scheduling contract, swept over random
    // push/pop interleavings: a pop yields the oldest waiting interactive
    // request whenever any interactive request is queued, else the oldest
    // batch request — strict priority, FIFO within a class, nothing lost.
    use silq::serve::{AdmissionQueue, GenRequest, Priority};
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x9107);
        let q = AdmissionQueue::new(1024);
        // model: (id, priority) in arrival order for everything queued
        let mut model: Vec<(u64, Priority)> = Vec::new();
        let mut next_id = 0u64;
        let mut popped = 0usize;
        for _ in 0..rng.range(20, 120) {
            if model.is_empty() || rng.below(5) < 3 {
                let pr = if rng.below(3) == 0 { Priority::Batch } else { Priority::Interactive };
                let r = GenRequest::new(next_id, vec![1, 2], 1).with_priority(pr);
                q.try_submit(r).unwrap_or_else(|e| panic!("seed {seed}: submit: {e}"));
                model.push((next_id, pr));
                next_id += 1;
            } else {
                let got = q.try_pop().unwrap_or_else(|| panic!("seed {seed}: queue lost a request"));
                let want = model
                    .iter()
                    .position(|(_, p)| *p == Priority::Interactive)
                    .unwrap_or(0);
                let (id, pr) = model.remove(want);
                assert_eq!(
                    (got.id, got.priority),
                    (id, pr),
                    "seed {seed}: pop inverted priority order (model {model:?})"
                );
                popped += 1;
            }
        }
        // drain what's left: all interactive (in order) before any batch
        let mut last = Priority::Interactive;
        while let Some(r) = q.try_pop() {
            assert!(
                !(last == Priority::Batch && r.priority == Priority::Interactive),
                "seed {seed}: an interactive request was stuck behind batch"
            );
            last = r.priority;
            popped += 1;
        }
        assert_eq!(popped as u64, next_id, "seed {seed}: requests leaked");
        assert_eq!(q.depth(), 0);
    }
}

#[test]
fn prop_deadline_eviction_deterministic_across_thread_widths() {
    // Deadline enforcement must be scheduler-state arithmetic, never a
    // race: a request whose completion deadline is already expired at
    // admission always decodes exactly one token before the next step
    // boundary evicts it, and every surviving request's tokens are
    // bit-identical to an undeadlined run — at any worker-pool width
    // (scripts/check.sh runs this suite under SILQ_THREADS=1 and =4).
    use silq::hostmodel::{host_test_params, CacheStore, HostCfg};
    use silq::serve::{serve_inline, FinishReason, GenRequest, HostBackend};
    let _traffic = hostmodel_traffic_lock();
    silq::kernels::pool::configure(silq::kernels::pool::env_threads().unwrap_or(1));
    let cases = if cfg!(debug_assertions) { 6 } else { 16 };
    for seed in 0..cases {
        let mut rng = Rng::new(seed ^ 0xDEAD);
        let lanes = rng.range(1, 4);
        let cfg = HostCfg {
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 24,
            policy: "w4a8kv8".parse().unwrap(),
            rope_theta: 10000.0,
        };
        let params = host_test_params(&cfg, seed);
        let store = CacheStore::for_policy(&cfg.policy);
        let n_req = rng.range(lanes + 1, 2 * lanes + 5);
        // a random subset carries an already-expired completion deadline
        let doomed: Vec<bool> = (0..n_req).map(|_| rng.below(3) == 0).collect();
        let prompts: Vec<Vec<i32>> = (0..n_req)
            .map(|_| {
                let plen = rng.range(1, 6);
                (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect()
            })
            .collect();
        let mk = |with_deadlines: bool| -> Vec<GenRequest> {
            prompts
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let mut r = GenRequest::new(i as u64, p.clone(), 8).ignore_eos();
                    if with_deadlines && doomed[i] {
                        r = r.with_deadline_ms(0);
                    }
                    r
                })
                .collect()
        };
        let run = |reqs: Vec<GenRequest>| {
            let b = HostBackend::new(cfg.clone(), lanes, &params, store).unwrap();
            let (mut rs, stats) = serve_inline(b, lanes, reqs).unwrap();
            rs.sort_by_key(|r| r.id);
            (rs, stats)
        };
        let (dead_a, stats_a) = run(mk(true));
        let (dead_b, _) = run(mk(true));
        let (free, _) = run(mk(false));
        let n_doomed = doomed.iter().filter(|&&d| d).count();
        assert_eq!(stats_a.deadline_evicted, n_doomed, "seed {seed}");
        for i in 0..n_req {
            let (a, b) = (&dead_a[i], &dead_b[i]);
            // rerun determinism: byte-for-byte the same outcome
            assert_eq!(a.tokens, b.tokens, "seed {seed} req {i}: rerun diverged");
            assert_eq!(a.reason, b.reason, "seed {seed} req {i}");
            if doomed[i] {
                assert_eq!(
                    a.reason,
                    FinishReason::DeadlineEvicted,
                    "seed {seed} req {i}: expired deadline must evict"
                );
                assert_eq!(
                    a.generated().len(),
                    1,
                    "seed {seed} req {i}: eviction lands at the first step boundary"
                );
            } else {
                assert_eq!(a.reason, FinishReason::Completed, "seed {seed} req {i}");
                // deadline traffic on sibling lanes never perturbs
                // surviving requests' numerics
                assert_eq!(
                    a.tokens, free[i].tokens,
                    "seed {seed} req {i}: deadline evictions changed sibling decode"
                );
            }
        }
    }
}
