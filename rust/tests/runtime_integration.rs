//! Integration: PJRT runtime loads and executes the AOT artifacts, and the
//! numerics match the Python-side fixtures exactly where they must.
//!
//! Requires `make artifacts` to have been run (skips otherwise).

use silq::config::Manifest;
use silq::model::{ParamStore, TensorBundle};
use silq::runtime::{build_inputs, literal_i32, literal_scalar, to_f32_vec, Engine};

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::new("artifacts").expect("engine"))
}

#[test]
fn manifest_and_engine_load() {
    let Some(eng) = engine() else { return };
    let _ = Manifest::load("artifacts").unwrap();
    assert!(eng.manifest.artifacts.len() >= 15);
}

#[test]
fn fwd_fp16_matches_python_fixture() {
    let Some(eng) = engine() else { return };
    let fixture = std::path::Path::new("artifacts/fixtures/fwd_tiny_fp16.bin");
    if !fixture.exists() {
        return;
    }
    let m = eng.module("tiny_fp16_fwd").expect("module");
    let b = TensorBundle::load(fixture).unwrap();
    let params = ParamStore::load_from_bundle(&m.spec, &b).unwrap();
    let tokens = b.get("tokens").unwrap().as_i32().unwrap().to_vec();
    let tok_spec = &m.spec.inputs[m.spec.input_index("tokens").unwrap()];
    let inputs = build_inputs(
        &m.spec,
        &params,
        &[("tokens", literal_i32(&tok_spec.dims, &tokens).unwrap())],
    )
    .unwrap();
    let out = m.run(&inputs).expect("run");
    let logits = to_f32_vec(&out[0]).unwrap();
    let want = b.f32s("logits").unwrap();
    assert_eq!(logits.len(), want.len());
    let max_diff = logits
        .iter()
        .zip(want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-3, "fp16 fwd mismatch: {max_diff}");
}

#[test]
fn fwd_quantized_matches_python_fixture() {
    let Some(eng) = engine() else { return };
    let fixture = std::path::Path::new("artifacts/fixtures/fwd_tiny_a8s.bin");
    if !fixture.exists() {
        return;
    }
    let m = eng.module("tiny_a8s-c8-w4_fwd").expect("module");
    let b = TensorBundle::load(fixture).unwrap();
    let params = ParamStore::load_from_bundle(&m.spec, &b).unwrap();
    let tokens = b.get("tokens").unwrap().as_i32().unwrap().to_vec();
    let tok_spec = &m.spec.inputs[m.spec.input_index("tokens").unwrap()];
    let inputs = build_inputs(
        &m.spec,
        &params,
        &[("tokens", literal_i32(&tok_spec.dims, &tokens).unwrap())],
    )
    .unwrap();
    let out = m.run(&inputs).expect("run");
    let logits = to_f32_vec(&out[0]).unwrap();
    let want = b.f32s("logits").unwrap();
    // quantized path: discontinuities allow isolated bin flips, but the
    // overwhelming majority of entries must agree tightly.
    let mut diffs: Vec<f32> = logits.iter().zip(want).map(|(a, b)| (a - b).abs()).collect();
    diffs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // cross-compiler (jax XLA vs xla_extension 0.5.1) 1-ulp differences can
    // flip isolated round() bins; require tight agreement for the bulk and
    // bounded flips for the tail.
    let p90 = diffs[(diffs.len() as f64 * 0.90) as usize];
    let p9999 = diffs[(diffs.len() as f64 * 0.9999) as usize];
    assert!(p90 < 1e-3, "quantized fwd p90 diff {p90}");
    assert!(p9999 < 0.2, "quantized fwd p99.99 diff {p9999}");
}

#[test]
fn train_step_matches_python_fixture() {
    let Some(eng) = engine() else { return };
    let fixture = std::path::Path::new("artifacts/fixtures/train_tiny_a8s.bin");
    if !fixture.exists() {
        return;
    }
    let m = eng.module("tiny_a8s-c8-w4_train").expect("module");
    let b = TensorBundle::load(fixture).unwrap();
    let params = ParamStore::load_from_bundle(&m.spec, &b).unwrap();

    let spec = &m.spec;
    let mut inputs = Vec::new();
    for t in &spec.inputs {
        if let Some(p) = t.name.strip_prefix("params.") {
            inputs.push(silq::runtime::literal_f32(&t.dims, params.get(p).unwrap()).unwrap());
        } else if t.name.starts_with("m.") || t.name.starts_with("v.") {
            inputs.push(silq::runtime::literal_f32(&t.dims, &vec![0.0; t.numel()]).unwrap());
        } else if t.name == "tokens" {
            inputs.push(literal_i32(&t.dims, b.get("tokens").unwrap().as_i32().unwrap()).unwrap());
        } else if t.name == "teacher_logits" {
            inputs.push(silq::runtime::literal_f32(&t.dims, b.f32s("teacher").unwrap()).unwrap());
        } else {
            let v = match t.name.as_str() {
                "lr" => 5e-3,
                "act_lrx" => 50.0,
                "kd_ratio" => 1.0,
                "kd_temp" => 1.0,
                "wd" => 0.1,
                "step" => 1.0,
                other => panic!("unexpected input {other}"),
            };
            inputs.push(literal_scalar(v));
        }
    }
    let out = m.run(&inputs).expect("run");
    let loss = silq::runtime::to_f32_scalar(&out[spec.output_index("loss").unwrap()]).unwrap();
    let want_loss = b.scalar("loss").unwrap();
    assert!((loss - want_loss).abs() < 2e-3, "loss {loss} vs {want_loss}");

    let gnorm = silq::runtime::to_f32_scalar(&out[spec.output_index("gnorm").unwrap()]).unwrap();
    assert!((gnorm - b.scalar("gnorm").unwrap()).abs() < 2e-2);

    for (out_name, fix_name) in [
        ("params.ln_f", "new.ln_f"),
        ("params.sa_x1", "new.sa_x1"),
        ("params.head", "new.head"),
        ("m.head", "newm.head"),
        ("v.head", "newv.head"),
    ] {
        let got = to_f32_vec(&out[spec.output_index(out_name).unwrap()]).unwrap();
        let want = b.f32s(fix_name).unwrap();
        let mut diffs: Vec<f32> =
            got.iter().zip(want).map(|(a, b)| (a - b).abs()).collect();
        diffs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = diffs[(diffs.len() as f64 * 0.99) as usize];
        // isolated quantization bin flips move an Adam update by up to
        // ~2*lr on first step (sign flip of m/sqrt(v)); bound the tail by that.
        let maxd = diffs[diffs.len() - 1];
        assert!(p99 < 5e-4, "{out_name} p99 diff {p99}");
        assert!(maxd < 2.5 * 5e-3, "{out_name} max diff {maxd}");
    }
}

#[test]
fn pallas_composed_artifact_runs() {
    // The tiny-pallas fwd artifact contains the lowered L1 kernels; running
    // it through the Rust PJRT client proves the full L1->L2->L3 stack.
    let Some(eng) = engine() else { return };
    let m = eng.module("tiny-pallas_a8d-c8-w4_fwd").expect("module");
    let mc = eng.manifest.model("tiny-pallas").unwrap().clone();
    let mut rng = silq::util::Rng::new(0);
    let params = ParamStore::init(&m.spec, &mc, &mut rng);
    let tok_spec = &m.spec.inputs[m.spec.input_index("tokens").unwrap()];
    let tokens: Vec<i32> = (0..tok_spec.numel()).map(|i| 1 + (i as i32 % 250)).collect();
    let inputs = build_inputs(
        &m.spec,
        &params,
        &[("tokens", literal_i32(&tok_spec.dims, &tokens).unwrap())],
    )
    .unwrap();
    let out = m.run(&inputs).expect("pallas-composed artifact must run on CPU PJRT");
    let logits = to_f32_vec(&out[0]).unwrap();
    assert!(logits.iter().all(|v| v.is_finite()));
    assert!(logits.iter().any(|v| *v != 0.0));
}

#[test]
fn calib_artifact_produces_ordered_quantiles() {
    let Some(eng) = engine() else { return };
    let m = eng.module("tiny_fp16_calib").expect("module");
    let fixture = std::path::Path::new("artifacts/fixtures/fwd_tiny_fp16.bin");
    if !fixture.exists() {
        return;
    }
    let b = TensorBundle::load(fixture).unwrap();
    let params = ParamStore::load_from_bundle(&m.spec, &b).unwrap();
    let tok_spec = &m.spec.inputs[m.spec.input_index("tokens").unwrap()];
    let tokens = b.get("tokens").unwrap().as_i32().unwrap().to_vec();
    let inputs = build_inputs(
        &m.spec,
        &params,
        &[("tokens", literal_i32(&tok_spec.dims, &tokens).unwrap())],
    )
    .unwrap();
    let out = m.run(&inputs).expect("calib run");
    let qs = to_f32_vec(&out[m.spec.output_index("qs_x1").unwrap()]).unwrap();
    for row in qs.chunks(4) {
        assert!(row[0] <= row[1] + 1e-6 && row[1] <= row[2] + 1e-6 && row[2] <= row[3] + 1e-6);
        assert!(row[3] > 0.0);
    }
    let gram = to_f32_vec(&out[m.spec.output_index("gram_x1").unwrap()]).unwrap();
    let d = 128;
    for l in 0..4 {
        let g = &gram[l * d * d..(l + 1) * d * d];
        for i in 0..d {
            assert!(g[i * d + i] >= 0.0);
        }
    }
}
