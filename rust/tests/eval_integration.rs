//! Eval harness integration: scoring machinery sanity on real artifacts.

use silq::data::{Suite, Vocab, World};
use silq::evalharness::Evaluator;
use silq::runtime::Engine;
use silq::train::init_model;

fn ready() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

#[test]
fn untrained_model_scores_near_chance() {
    if !ready() {
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let params = init_model(&engine, "tiny_fp16_fwd", 11).unwrap();
    let world = World::generate(Vocab::new(256), 5);
    let ev = Evaluator::new(&engine, "tiny_fp16_fwd", false, 24).unwrap();
    let r = ev.eval_suites(&params, &world, &[Suite::Csr], 1).unwrap();
    // 8 CSR tasks with 2-4 choices: chance is 0.25-0.5; an untrained model
    // must sit in a broad band around it (not 0, not high)
    let avg = r.suite_avg(Suite::Csr);
    assert!(avg > 0.03 && avg < 0.70, "untrained CSR avg {avg}");
}

#[test]
fn generation_returns_requested_tokens() {
    if !ready() {
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let params = init_model(&engine, "tiny_fp16_fwd", 12).unwrap();
    let ev = Evaluator::new(&engine, "tiny_fp16_fwd", false, 4).unwrap();
    let prompts = vec![vec![1i32, 40, 12, 41, 15], vec![1i32, 50, 12, 33, 15]];
    let outs = ev.generate(&params, &prompts, 3).unwrap();
    assert_eq!(outs.len(), 2);
    assert!(outs.iter().all(|o| o.len() == 3));
    assert!(outs.iter().flatten().all(|&t| (0..256).contains(&t)));
}

#[test]
fn report_covers_all_suites() {
    if !ready() {
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let params = init_model(&engine, "tiny_fp16_fwd", 13).unwrap();
    let world = World::generate(Vocab::new(256), 5);
    let ev = Evaluator::new(&engine, "tiny_fp16_fwd", true, 8).unwrap();
    let r = ev.eval_all(&params, &world, 2).unwrap();
    assert_eq!(r.per_task.len(), 20);
    assert_eq!(r.per_task.iter().filter(|(_, s, _)| *s == Suite::Csr).count(), 8);
    assert_eq!(r.per_task.iter().filter(|(_, s, _)| *s == Suite::OllmV1).count(), 6);
    assert_eq!(r.per_task.iter().filter(|(_, s, _)| *s == Suite::OllmV2).count(), 6);
}
