//! Eval harness integration: scoring machinery sanity on real artifacts,
//! plus the artifact-free host-backend path that runs in a bare checkout.

use silq::data::{Suite, Vocab, World};
use silq::evalharness::Evaluator;
use silq::forward::{ArtifactForward, HostForward};
use silq::hostmodel::{builtin_model, builtin_prec, host_test_params, CacheStore, HostCfg};
use silq::runtime::Engine;
use silq::train::init_model;

fn ready() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

#[test]
fn untrained_model_scores_near_chance() {
    if !ready() {
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let params = init_model(&engine, "tiny_fp16_fwd", 11).unwrap();
    let world = World::generate(Vocab::new(256), 5);
    let fwd = ArtifactForward::new(&engine, "tiny_fp16_fwd", &params).unwrap();
    let mut ev = Evaluator::new(fwd, false, 24);
    let r = ev.eval_suites(&world, &[Suite::Csr], 1).unwrap();
    // 8 CSR tasks with 2-4 choices: chance is 0.25-0.5; an untrained model
    // must sit in a broad band around it (not 0, not high)
    let avg = r.suite_avg(Suite::Csr);
    assert!(avg > 0.03 && avg < 0.70, "untrained CSR avg {avg}");
}

#[test]
fn generation_returns_requested_tokens() {
    if !ready() {
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let params = init_model(&engine, "tiny_fp16_fwd", 12).unwrap();
    let fwd = ArtifactForward::new(&engine, "tiny_fp16_fwd", &params).unwrap();
    let mut ev = Evaluator::new(fwd, false, 4);
    let prompts = vec![vec![1i32, 40, 12, 41, 15], vec![1i32, 50, 12, 33, 15]];
    let outs = ev.generate(&prompts, 3).unwrap();
    assert_eq!(outs.len(), 2);
    assert!(outs.iter().all(|o| o.len() == 3));
    assert!(outs.iter().flatten().all(|&t| (0..256).contains(&t)));
}

#[test]
fn report_covers_all_suites() {
    if !ready() {
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let params = init_model(&engine, "tiny_fp16_fwd", 13).unwrap();
    let world = World::generate(Vocab::new(256), 5);
    let fwd = ArtifactForward::new(&engine, "tiny_fp16_fwd", &params).unwrap();
    let mut ev = Evaluator::new(fwd, true, 8);
    let r = ev.eval_all(&world, 2).unwrap();
    assert_eq!(r.per_task.len(), 20);
    assert_eq!(r.per_task.iter().filter(|(_, s, _)| *s == Suite::Csr).count(), 8);
    assert_eq!(r.per_task.iter().filter(|(_, s, _)| *s == Suite::OllmV1).count(), 6);
    assert_eq!(r.per_task.iter().filter(|(_, s, _)| *s == Suite::OllmV2).count(), 6);
}

/// The acceptance-criterion path: a full `EvalReport` out of the host
/// backend with nothing compiled on disk — built-in configs describe the
/// model, scoring runs the batched host forward, generation runs the
/// incremental KV decode.
#[test]
fn host_backend_produces_full_report_without_artifacts() {
    let mc = builtin_model("tiny").unwrap();
    let pc = builtin_prec("a8d-c8-w4").unwrap();
    let hc = HostCfg::from_cfgs(&mc, &pc).unwrap();
    let params = host_test_params(&hc, 31);
    let fwd = HostForward::new(hc, mc.fwd_batch, &params, CacheStore::Int8).unwrap();
    let world = World::generate(Vocab::new(mc.vocab), 5);
    let mut ev = Evaluator::new(fwd, false, 2);
    let r = ev.eval_all(&world, 2).unwrap();
    assert_eq!(r.per_task.len(), 20, "every registry task must be scored");
    assert!(r.per_task.iter().all(|(_, _, a)| (0.0..=1.0).contains(a)));
    // summary covers all three suites without panicking
    let s = r.summary();
    assert!(s.contains("CSR"));
}
