//! PTQ shoot-out: RTN vs SmoothQuant vs GPTQ vs SpinQuant-analog vs SiLQ on
//! the same instruct model — the qualitative core of the paper's Table 1.
//!
//! Run: `cargo run --release --offline --example ptq_compare -- [qat_steps]`

use anyhow::Result;
use silq::config::TrainCfg;
use silq::coordinator::{Pipeline, PipelineCfg};
use silq::data::{DataMix, SftStyle, Suite};
use silq::metrics::{RunLog, Table};
use silq::runtime::Engine;

fn main() -> Result<()> {
    let qat_steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let engine = Engine::new("artifacts")?;
    let p = Pipeline::new(&engine, PipelineCfg { qat_steps, eval_items: 40, ..Default::default() })?;
    let mut log = RunLog::new("runs/ptq_compare");

    let fp16 = p.instruct_model(SftStyle::TuluSynth, "instruct", &mut log)?;
    let stats = p.calib_stats(&fp16, 4)?;
    let prec = "a8d-c8-w4";

    let mut t = Table::new(&["method", "CSR", "OLLMv1", "OLLMv2"]);
    let mut add = |name: &str, r: &silq::evalharness::EvalReport| {
        t.row(&[
            name.into(),
            format!("{:.2}", 100.0 * r.suite_avg(Suite::Csr)),
            format!("{:.2}", 100.0 * r.suite_avg(Suite::OllmV1)),
            format!("{:.2}", 100.0 * r.suite_avg(Suite::OllmV2)),
        ]);
    };

    add("fp16 baseline", &p.eval("fp16", &fp16, true)?);
    for method in ["rtn", "smoothquant", "gptq", "spinquant"] {
        log.note(&format!("[ptq] {method}..."));
        let qs = p.ptq_baseline(method, prec, &fp16, &stats)?;
        add(method, &p.eval(prec, &qs, true)?);
    }

    log.note("[ptq] silq (QAT)...");
    let mut qs = p.calibrated_quant_store(prec, &fp16, &stats)?;
    let tcfg = p.qat_cfg(qat_steps);
    p.qat(prec, &mut qs, &fp16, DataMix::Instruct { style: SftStyle::TuluSynth, dclm_ratio: 0.25 }, tcfg, &mut log, None)?;
    add("silq (QAT+KD)", &p.eval(prec, &qs, true)?);

    println!("\n{}", t.render());
    Ok(())
}
