//! Quickstart: load the compiled artifacts, quantize a freshly-initialized
//! model with the paper's calibration rules, and compare fp16 vs quantized
//! logits — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --offline --example quickstart`

use anyhow::Result;
use silq::coordinator::{Pipeline, PipelineCfg};
use silq::data::vocab::Vocab;
use silq::data::{CorpusGen, World};
use silq::metrics::RunLog;
use silq::runtime::{build_inputs, literal_i32, to_f32_vec, Engine};
use silq::train::init_model;

fn main() -> Result<()> {
    // 1. the engine loads + compiles AOT artifacts (HLO text -> PJRT)
    let engine = Engine::new("artifacts")?;
    println!("PJRT platform: {}", engine.platform());

    // 2. a fresh tiny fp16 model and a synthetic world
    let params = init_model(&engine, "tiny_fp16_fwd", 42)?;
    let mc = engine.manifest.model("tiny")?.clone();
    let world = World::generate(Vocab::new(mc.vocab), 7);
    let mut corpus = CorpusGen::new(&world, 0);
    println!("corpus sample: {}", world.vocab.describe_seq(&corpus.sentence()));

    // 3. run the fp16 forward pass
    let m = engine.module("tiny_fp16_fwd")?;
    let tok_spec = m.spec.inputs[m.spec.input_index("tokens")?].clone();
    let mut tokens = vec![0i32; tok_spec.numel()];
    for row in tokens.chunks_mut(mc.seq_len) {
        row.copy_from_slice(&corpus.document(mc.seq_len));
    }
    let out = m.run(&build_inputs(&m.spec, &params, &[("tokens", literal_i32(&tok_spec.dims, &tokens)?)])?)?;
    let logits = to_f32_vec(&out[0])?;
    println!("fp16 logits[0..4] = {:?}", &logits[..4]);

    // 4. calibrate + run the a8d-c8-w4 quantized variant of the same weights
    let cfg = PipelineCfg { eval_items: 8, ..Default::default() };
    let p = Pipeline::new(&engine, cfg)?;
    let mut log = RunLog::ephemeral();
    log.note("calibrating quantizers (percentile + convex-MSE)...");
    let stats = p.calib_stats(&params, 2)?;
    let qs = p.calibrated_quant_store("a8d-c8-w4", &params, &stats)?;

    let mq = engine.module("tiny_a8d-c8-w4_fwd")?;
    let outq = mq.run(&build_inputs(&mq.spec, &qs, &[("tokens", literal_i32(&tok_spec.dims, &tokens)?)])?)?;
    let logits_q = to_f32_vec(&outq[0])?;
    println!("quant logits[0..4] = {:?}", &logits_q[..4]);

    let mse: f32 = logits.iter().zip(&logits_q).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
        / logits.len() as f32;
    println!("fp16-vs-int4 logit MSE (untrained weights): {mse:.6}");
    println!("quickstart OK — next: examples/qat_e2e.rs for the full pipeline");
    Ok(())
}
