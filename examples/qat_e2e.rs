//! End-to-end driver (DESIGN.md §5): pretrain a transformer LM on the
//! synthetic corpus (loss curve logged), SFT it into an instruct model,
//! calibrate, run SiLQ QAT with knowledge distillation at A8d-C8-W4, and
//! evaluate fp16 vs quantized on all three benchmark suites.
//!
//! Run: `cargo run --release --offline --example qat_e2e -- [model] [steps]`
//! Defaults: tiny, pretrain 500 / sft 250 / qat 250. The `small` (~5.5M
//! param) configuration is the showcase; results land in EXPERIMENTS.md.

use anyhow::Result;
use silq::config::TrainCfg;
use silq::coordinator::{Pipeline, PipelineCfg};
use silq::data::{DataMix, SftStyle, Suite};
use silq::metrics::{RunLog, Table};
use silq::runtime::Engine;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().cloned().unwrap_or_else(|| "tiny".into());
    let qat_steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(250);

    let engine = Engine::new("artifacts")?;
    let cfg = PipelineCfg {
        model: model.clone(),
        pretrain_steps: qat_steps * 2,
        sft_steps: qat_steps,
        qat_steps,
        eval_items: 40,
        ..Default::default()
    };
    let p = Pipeline::new(&engine, cfg)?;
    let mut log = RunLog::new(format!("runs/e2e_{model}"));

    // ---- phase 1+2: fp16 pretrain + SFT (cached across runs) ----
    log.note(&format!("[e2e] model={model} qat_steps={qat_steps}"));
    let fp16 = p.instruct_model(SftStyle::TuluSynth, "instruct", &mut log)?;

    // ---- phase 3: calibration ----
    log.note("[e2e] collecting calibration statistics (quantile + Gram)...");
    let stats = p.calib_stats(&fp16, 4)?;
    let prec = "a8d-c8-w4";
    let mut qs = p.calibrated_quant_store(prec, &fp16, &stats)?;

    // ---- phase 4: SiLQ QAT with KD ----
    log.note("[e2e] QAT with knowledge distillation...");
    let tcfg = p.qat_cfg(qat_steps);
    let st = p.qat(
        prec,
        &mut qs,
        &fp16,
        DataMix::Instruct { style: SftStyle::TuluSynth, dclm_ratio: 0.25 },
        tcfg,
        &mut log,
        None,
    )?;
    log.note(&format!(
        "[e2e] QAT: {:.2} steps/s (exec {:.0}% teacher {:.0}% data {:.0}% host {:.0}%), final loss {:.4}",
        st.steps_per_sec(),
        100.0 * st.exec_secs / st.total_secs,
        100.0 * st.teacher_secs / st.total_secs,
        100.0 * st.data_secs / st.total_secs,
        100.0 * st.host_secs / st.total_secs,
        st.final_loss
    ));
    // loss curve (sampled)
    let n = log.losses.len();
    let curve: Vec<String> = (0..10.min(n))
        .map(|i| {
            let (s, l) = log.losses[i * n.max(1) / 10.min(n).max(1)];
            format!("{s}:{l:.3}")
        })
        .collect();
    println!("[e2e] loss curve (step:loss): {}", curve.join(" "));

    // ---- phase 5: evaluation ----
    log.note("[e2e] evaluating fp16 vs quantized...");
    let r_fp = p.eval("fp16", &fp16, true)?;
    let r_q = p.eval(prec, &qs, true)?;
    let mut t = Table::new(&["model", "CSR", "OLLMv1", "OLLMv2"]);
    for (name, r) in [("fp16 instruct", &r_fp), ("SiLQ a8d-c8-w4", &r_q)] {
        t.row(&[
            name.into(),
            format!("{:.2}", 100.0 * r.suite_avg(Suite::Csr)),
            format!("{:.2}", 100.0 * r.suite_avg(Suite::OllmV1)),
            format!("{:.2}", 100.0 * r.suite_avg(Suite::OllmV2)),
        ]);
    }
    println!("\n{}", t.render());
    qs.save(format!("runs/e2e_{model}/quantized.ckpt"))?;
    println!("[e2e] quantized checkpoint saved; done.");
    Ok(())
}
