//! Serve a quantized checkpoint: batched greedy generation through the
//! compiled a8d-c8-w4 forward artifact — the deployment-shaped path (the
//! paper's motivation is low-latency inference on NorthPole-class
//! accelerators; here the same integer-constrained graph runs on CPU PJRT).
//!
//! Run: `cargo run --release --offline --example serve_quantized -- [ckpt]`
//! Without a checkpoint it calibrates a fresh model (answers will be noise,
//! but latency/throughput reporting still stands).

use anyhow::Result;
use silq::coordinator::{Pipeline, PipelineCfg};
use silq::data::vocab::{self, Vocab};
use silq::data::World;
use silq::evalharness::Evaluator;
use silq::metrics::RunLog;
use silq::model::ParamStore;
use silq::train::init_model;
use silq::util::Timer;

fn main() -> Result<()> {
    let engine = silq::runtime::Engine::new("artifacts")?;
    let prec = "a8d-c8-w4";
    let art = format!("tiny_{prec}_fwd");
    let spec = engine.module(&art)?.spec.clone();

    // load a trained quantized checkpoint if given, else calibrate a fresh one
    let params: ParamStore = match std::env::args().nth(1) {
        Some(path) => {
            println!("loading checkpoint {path}");
            ParamStore::load(&spec, &path)?
        }
        None => {
            println!("no checkpoint given; calibrating a fresh (untrained) model");
            let fp16 = init_model(&engine, "tiny_fp16_fwd", 0)?;
            let p = Pipeline::new(&engine, PipelineCfg { eval_items: 4, ..Default::default() })?;
            let mut log = RunLog::ephemeral();
            log.note("calibrating...");
            let stats = p.calib_stats(&fp16, 2)?;
            p.calibrated_quant_store(prec, &fp16, &stats, "quantile", "mse")?
        }
    };

    let mc = engine.manifest.model("tiny")?.clone();
    let world = World::generate(Vocab::new(mc.vocab), 7);
    let ev = Evaluator::new(&engine, &art, true, 4)?;

    // a batch of "requests": chat-format questions about the world
    let v = &world.vocab;
    let prompts: Vec<Vec<i32>> = (0..8)
        .map(|i| {
            vec![
                vocab::BOS, vocab::Q,
                Vocab::attr_type(i % 4), vocab::OF, v.entity(i * 3 % world.n_entities()),
                vocab::A,
            ]
        })
        .collect();

    println!("serving {} requests (batched greedy decode, 4 new tokens)...", prompts.len());
    let t = Timer::start();
    let outs = ev.generate(&params, &prompts, 4)?;
    let ms = t.millis();
    for (p, o) in prompts.iter().zip(&outs) {
        println!("  {:<40} -> {}", v.describe_seq(p), v.describe_seq(o));
    }
    println!(
        "latency: {:.1} ms total, {:.1} ms/request, {:.0} generated tok/s",
        ms,
        ms / prompts.len() as f64,
        (prompts.len() * 4) as f64 / ms * 1e3
    );

    // deployment-path check: pack the head weights to integers and verify
    // the packed representation is lossless vs the fake-quant values
    let head = params.get("head")?;
    let sw = params.get("sw_head")?;
    let cols = params.shape("head")?[1];
    let packed = silq::quant::pack::PackedTensor::pack(head, cols, sw, 8)?;
    println!(
        "head packed for deployment: {} KiB (fp32 would be {} KiB)",
        packed.storage_bytes() / 1024,
        head.len() * 4 / 1024
    );
    Ok(())
}
