//! Serve a quantized checkpoint through the continuous-batching engine:
//! requests flow admission queue -> scheduler -> decode backend, with
//! per-request TTFT/latency and aggregate throughput reported — the
//! deployment-shaped path (the paper's motivation is low-latency inference
//! on NorthPole-class accelerators; here the same integer-constrained
//! graph runs on CPU PJRT, and the host backend shows the K/V cache
//! resident in the paper's 8-bit integer representation).
//!
//! Run: `cargo run --release --offline --example serve_quantized -- [ckpt]`
//! Without a checkpoint it calibrates a fresh model (answers will be noise,
//! but latency/throughput reporting still stands).

use anyhow::Result;
use silq::coordinator::{Pipeline, PipelineCfg};
use silq::data::vocab::{self, Vocab};
use silq::data::World;
use silq::metrics::RunLog;
use silq::model::ParamStore;
use silq::serve::{
    serve_inline, ArtifactBackend, CacheStore, GenRequest, HostBackend, HostCfg,
};
use silq::train::init_model;

fn main() -> Result<()> {
    let engine = silq::runtime::Engine::new("artifacts")?;
    let prec = "a8d-c8-w4";
    let art = format!("tiny_{prec}_fwd");
    let spec = engine.module(&art)?.spec.clone();

    // load a trained quantized checkpoint if given, else calibrate a fresh one
    let params: ParamStore = match std::env::args().nth(1) {
        Some(path) => {
            println!("loading checkpoint {path}");
            ParamStore::load(&spec, &path)?
        }
        None => {
            println!("no checkpoint given; calibrating a fresh (untrained) model");
            let fp16 = init_model(&engine, "tiny_fp16_fwd", 0)?;
            let p = Pipeline::new(&engine, PipelineCfg { eval_items: 4, ..Default::default() })?;
            let mut log = RunLog::ephemeral();
            log.note("calibrating...");
            let stats = p.calib_stats(&fp16, 2)?;
            p.calibrated_quant_store(prec, &fp16, &stats)?
        }
    };

    let mc = engine.manifest.model("tiny")?.clone();
    let pc = engine.manifest.prec(prec)?.clone();
    let world = World::generate(Vocab::new(mc.vocab), 7);
    let v = world.vocab.clone();

    // a stream of "requests": chat-format questions about the world
    let requests = |n: usize, max_new: usize| -> Vec<GenRequest> {
        (0..n)
            .map(|i| {
                let prompt = vec![
                    vocab::BOS, vocab::Q,
                    Vocab::attr_type(i % 4), vocab::OF, v.entity(i * 3 % world.n_entities()),
                    vocab::A,
                ];
                GenRequest::new(i as u64, prompt, max_new)
            })
            .collect()
    };

    // 1) throughput path: continuous batching through the compiled artifact
    println!("\n== artifact backend: 8 requests, 4 new tokens each ==");
    let backend = ArtifactBackend::new(&engine, &art, &params)?;
    let (results, stats) = serve_inline(backend, 8, requests(8, 4))?;
    for r in &results {
        println!(
            "  {:<40} -> {}",
            v.describe_seq(&r.tokens[..r.prompt_len]),
            v.describe_seq(r.generated())
        );
    }
    println!("{}", stats.report());

    // 2) deployment path: host incremental decode, K/V cache resident as
    //    packed INT8 — must be token-identical to the f32 cache run
    println!("\n== host backend: int8 KV pool vs f32 cache ==");
    let cfg = HostCfg::from_cfgs(&mc, &pc)?;
    let b_i8 = HostBackend::new(cfg.clone(), 4, &params, CacheStore::Int8)?;
    let b_f32 = HostBackend::new(cfg, 4, &params, CacheStore::F32)?;
    let (mut r_i8, s_i8) = serve_inline(b_i8, 4, requests(8, 4))?;
    let (mut r_f32, _) = serve_inline(b_f32, 4, requests(8, 4))?;
    r_i8.sort_by_key(|r| r.id);
    r_f32.sort_by_key(|r| r.id);
    let identical =
        r_i8.iter().zip(&r_f32).all(|(a, b)| a.generated() == b.generated());
    println!(
        "int8 pool vs f32 cache: {} (kv pool peak {} KiB)",
        if identical { "token-identical" } else { "DIVERGED" },
        s_i8.kv_bytes_peak / 1024
    );
    anyhow::ensure!(identical, "integer cache must not change greedy output");
    Ok(())
}
