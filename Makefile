# SiLQ reproduction — top-level targets.
#
# `make check` is the tier-1 gate every PR must keep green (see ROADMAP.md).

.PHONY: check fmt artifacts bench bench-quick pytest soak chaos

# tier-1: release build + full test suite + clippy (-D warnings) + formatting
check:
	./scripts/check.sh

fmt:
	cd rust && cargo fmt

# AOT-lower every (model, precision, mode) artifact + manifest (needs JAX)
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
	cd python && python3 -m compile.fixtures --out-dir=../artifacts/fixtures

bench:
	cd rust && cargo bench --offline 2>&1 | tee ../bench_output.txt

# smoke bench: only the sections that regenerate the machine-readable perf
# trajectory (BENCH_serve.json + BENCH_hostmodel.json) — runs in seconds,
# suitable for CI
bench-quick:
	cd rust && cargo bench --offline -- --quick

pytest:
	cd python && python3 -m pytest tests/ -q

# long-seed serve soak (thousands of requests, forced rejections and
# evictions, KV-pool leak + stats-exactness invariants) — deliberately
# NOT part of tier-1; run locally before serve/scheduler changes
soak:
	cd rust && SILQ_SOAK=long cargo test --offline --release --test serve_soak -- --nocapture

# chaos soak: a seeded fault plan (KV alloc failures, shard stalls, torn
# frame writes, forced queue-full, a slowlorised request) driven through
# the live HTTP server, asserting the stats/obs/client ledgers balance
# exactly and /healthz recovers to ok after the storm (see rust/src/faults)
chaos:
	cd rust && cargo test --offline --release --test chaos_soak -- --nocapture
