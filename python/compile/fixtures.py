"""Generate cross-language fixtures: expected numerics the Rust test suite
replays against the compiled artifacts and against its host-side quant /
calibration implementations.

Format is the same "tensor bundle" the Rust checkpoint IO uses:

    magic  b"SILQTNSR"
    u32    version (1)
    u32    tensor count
    per tensor:
        u32 name_len, name (utf-8)
        u8  dtype (0 = f32, 1 = i32)
        u32 ndim, u32 dims...
        payload (little-endian)

Usage: python -m compile.fixtures --out-dir ../artifacts/fixtures
"""

import argparse
import os
import struct

import numpy as np
import jax.numpy as jnp

from . import model as M
from . import quant
from .configs import TINY, PRECISIONS
from .kernels import ref

MAGIC = b"SILQTNSR"


def write_bundle(path, tensors):
    """tensors: list of (name, np.ndarray) with dtype f32 or i32."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype == np.float32:
                dt = 0
            elif arr.dtype == np.int32:
                dt = 1
            else:
                raise ValueError(f"{name}: {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BI", dt, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def f32(x):
    return np.asarray(x, np.float32)


def quant_fixtures():
    rng = np.random.default_rng(100)
    out = []
    cases = [(8, 0.05), (4, 0.11), (16, 0.002), (2, 0.4)]
    for i, (bits, s) in enumerate(cases):
        x = (rng.standard_normal(257) * 2).astype(np.float32)
        y = ref.fake_quant_ref(jnp.asarray(x), s, bits)
        out += [(f"fq{i}.x", x), (f"fq{i}.s", f32([s])), (f"fq{i}.bits", np.asarray([bits], np.int32)),
                (f"fq{i}.y", np.asarray(y))]
    # dynamic per-row
    x = (rng.standard_normal((6, 64)) * 3).astype(np.float32)
    y = ref.dynamic_quant_ref(jnp.asarray(x), 8)
    out += [("dq.x", x), ("dq.y", np.asarray(y))]
    # per-channel
    w = rng.standard_normal((32, 16)).astype(np.float32)
    sw = (np.abs(rng.standard_normal(16)) * 0.1 + 0.01).astype(np.float32)
    y = ref.fake_quant_ref(jnp.asarray(w), jnp.asarray(sw)[None, :], 4)
    out += [("pc.w", w), ("pc.sw", sw), ("pc.y", np.asarray(y))]
    # MSE-calibrated steps (paper Eq. 2)
    for i, dist in enumerate(["normal", "heavy"]):
        w = (rng.standard_normal(1024) if dist == "normal"
             else rng.standard_t(df=3, size=1024) * 0.2).astype(np.float32)
        s4 = float(quant.weight_step_mse(jnp.asarray(w), 4))
        s8 = float(quant.weight_step_mse(jnp.asarray(w), 8))
        out += [(f"mse{i}.w", w), (f"mse{i}.s4", f32([s4])), (f"mse{i}.s8", f32([s8]))]
    # LSQ-init steps
    w = rng.standard_normal(512).astype(np.float32)
    out += [("lsqinit.w", w),
            ("lsqinit.s4", f32([float(quant.weight_step_lsq_init(jnp.asarray(w), 4))]))]
    # percentile calibration
    x = rng.standard_normal(50000).astype(np.float32)
    out += [("pct.x", x),
            ("pct.s8", f32([float(quant.act_step_percentile(jnp.asarray(x), 8, 99.99))])),
            ("pct.smax", f32([float(quant.act_step_max(jnp.asarray(x), 8))]))]
    # qmatmul
    xx = rng.standard_normal((24, 32)).astype(np.float32)
    ww = rng.standard_normal((32, 16)).astype(np.float32)
    sw = (np.abs(rng.standard_normal(16)) * 0.05 + 0.01).astype(np.float32)
    y = ref.qmatmul_ref(jnp.asarray(xx), jnp.asarray(ww), 0.04, jnp.asarray(sw), 8, 4)
    out += [("qmm.x", xx), ("qmm.w", ww), ("qmm.sw", sw), ("qmm.sx", f32([0.04])),
            ("qmm.y", np.asarray(y))]
    return out


def model_fixtures(pc_name):
    mc, pc = TINY, PRECISIONS[pc_name]
    params = M.init_params(mc, pc, seed=7)
    rng = np.random.default_rng(8)
    tokens = rng.integers(1, mc.vocab, (mc.fwd_batch, mc.seq_len)).astype(np.int32)
    logits = M.forward({k: jnp.asarray(v) for k, v in params.items()},
                       jnp.asarray(tokens), mc, pc)
    out = [(f"params.{k}", v) for k, v in params.items()]
    out += [("tokens", tokens), ("logits", np.asarray(logits))]
    return out


def train_fixture():
    mc, pc = TINY, PRECISIONS["a8s-c8-w4"]
    params = {k: jnp.asarray(v) for k, v in M.init_params(mc, pc, seed=7).items()}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    rng = np.random.default_rng(9)
    tokens = rng.integers(1, mc.vocab, (mc.train_batch, mc.seq_len)).astype(np.int32)
    teacher = rng.standard_normal((mc.train_batch, mc.seq_len, mc.vocab)).astype(np.float32)
    p1, m1, v1, loss, gnorm, ntp, kd = M.train_step(
        params, m, v, jnp.asarray(tokens), jnp.asarray(teacher),
        5e-3, 50.0, 1.0, 1.0, 0.1, 1.0, mc, pc)
    out = [(f"params.{k}", np.asarray(x)) for k, x in params.items()]
    out += [("tokens", tokens), ("teacher", teacher),
            ("loss", f32([float(loss)])), ("gnorm", f32([float(gnorm)])),
            ("ntp", f32([float(ntp)])), ("kd", f32([float(kd)])),
            ("new.ln_f", np.asarray(p1["ln_f"])), ("new.sa_x1", np.asarray(p1["sa_x1"])),
            ("new.head", np.asarray(p1["head"])), ("newm.head", np.asarray(m1["head"])),
            ("newv.head", np.asarray(v1["head"]))]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts/fixtures")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    write_bundle(os.path.join(args.out_dir, "quant_cases.bin"), quant_fixtures())
    write_bundle(os.path.join(args.out_dir, "fwd_tiny_fp16.bin"), model_fixtures("fp16"))
    write_bundle(os.path.join(args.out_dir, "fwd_tiny_a8s.bin"), model_fixtures("a8s-c8-w4"))
    write_bundle(os.path.join(args.out_dir, "train_tiny_a8s.bin"), train_fixture())
    print("fixtures written to", args.out_dir)


if __name__ == "__main__":
    main()
