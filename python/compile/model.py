"""L2: the quantized decoder-only transformer, its KD/NTP training step
(AdamW), and the calibration forward pass.

Architecture (Llama-style, matching the paper's targets): RMSNorm ->
causal attention with RoPE -> RMSNorm -> SwiGLU MLP, tied quantization
sites per the paper's Figure 2:

  * inputs to every linear layer: ``act_bits`` (8), static or dynamic
  * query / softmax-output matmul inputs: INT16; the softmax output tensor
    itself is left unquantized during training (paper section 3.2)
  * K/V cache tensors: ``cache_bits`` (4 or 8)
  * all linear weights: ``weight_bits`` (4), per output channel
  * final head: 8-bit input activations and weights; embedding fp16/f32

The layer stack is a ``lax.scan`` over stacked per-layer parameters: this
keeps the lowered HLO small and gives the Rust coordinator a short, stable
flat parameter list (see ``param_spec``).
"""

import numpy as np
import jax
import jax.numpy as jnp

from . import quant
from .configs import ModelConfig, PrecisionConfig
from .kernels import qmatmul as qkern

EPS = 1e-6
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-10  # paper Appendix B

# parameters that receive weight decay (2-D weight matrices only)
DECAY_PARAMS = ("embed", "head", "wq", "wk", "wv", "wo", "wg", "wu", "wd")


# ---------------------------------------------------------------------------
# Parameter specification — the contract with the Rust coordinator
# ---------------------------------------------------------------------------

def param_spec(mc: ModelConfig, pc: PrecisionConfig):
    """Ordered list of (name, shape) for every trainable tensor."""
    L, D, F, V = mc.n_layers, mc.d_model, mc.d_ff, mc.vocab
    spec = [
        ("embed", (V, D)),
        ("ln1", (L, D)), ("wq", (L, D, D)), ("wk", (L, D, D)), ("wv", (L, D, D)),
        ("wo", (L, D, D)),
        ("ln2", (L, D)), ("wg", (L, D, F)), ("wu", (L, D, F)), ("wd", (L, F, D)),
        ("ln_f", (D,)), ("head", (D, V)),
    ]
    if pc.quantized:
        spec += [
            ("sw_q", (L, D)), ("sw_k", (L, D)), ("sw_v", (L, D)), ("sw_o", (L, D)),
            ("sw_g", (L, F)), ("sw_u", (L, F)), ("sw_d", (L, D)), ("sw_head", (V,)),
        ]
        if not pc.act_dynamic:
            spec += [
                ("sa_x1", (L,)), ("sa_q", (L,)), ("sc_k", (L,)), ("sc_v", (L,)),
                ("sa_o", (L,)), ("sa_x2", (L,)), ("sa_d", (L,)), ("sa_head", ()),
            ]
    return spec


BLOCK_PARAMS = ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd",
                "sw_q", "sw_k", "sw_v", "sw_o", "sw_g", "sw_u", "sw_d",
                "sa_x1", "sa_q", "sc_k", "sc_v", "sa_o", "sa_x2", "sa_d")


def init_params(mc: ModelConfig, pc: PrecisionConfig, seed: int = 0):
    """Host-side init (numpy) — used by pytest; the Rust coordinator has its
    own equivalent initializer."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, shape in param_spec(mc, pc):
        if name.startswith("ln"):
            out[name] = np.ones(shape, np.float32)
        elif name.startswith("sw_") or name.startswith("sa_") or name.startswith("sc_"):
            out[name] = np.full(shape, 0.05, np.float32)
        else:
            std = 0.02 if name in ("embed", "head") else 1.0 / np.sqrt(shape[-2])
            out[name] = (rng.standard_normal(shape) * std).astype(np.float32)
    return out


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, g):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS)


def rope_tables(mc: ModelConfig):
    dh = mc.d_head
    inv = 1.0 / (mc.rope_theta ** (np.arange(0, dh, 2) / dh))
    t = np.arange(mc.seq_len)
    freqs = np.outer(t, inv)
    return jnp.asarray(np.cos(freqs), jnp.float32), jnp.asarray(np.sin(freqs), jnp.float32)


def apply_rope(x, cos, sin):
    # x: [B, H, S, dh]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c, s = cos[None, None], sin[None, None]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape)


def _hadamard(n: int) -> np.ndarray:
    h = np.array([[1.0]])
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return (h / np.sqrt(n)).astype(np.float32)


def act_quant(x, step, bits, pc: PrecisionConfig, numel: int):
    """Quantize an activation/cache tensor at a site.

    ``step`` is the learned scalar step (static mode) or None (dynamic
    per-token mode). ``numel`` is the per-step element count for the LSQ
    gradient scale.
    """
    if not pc.quantized:
        return x
    if pc.act_dynamic or step is None:
        return quant.ste_dynamic_quantize(x, bits)
    qn, qp = quant.qbounds(bits)
    g = quant.lsq_grad_scale(numel, qp)
    return quant.lsq_quantize(x, step, qn, qp, g)


def weight_quant(w, sw, bits, pc: PrecisionConfig):
    """Per-output-channel LSQ weight quantization. ``sw``: [out]."""
    if not pc.quantized:
        return w
    qn, qp = quant.qbounds(bits)
    g = quant.lsq_grad_scale(w.shape[-2], qp)
    return quant.lsq_quantize(w, sw[..., None, :], qn, qp, g)


def qlinear(x, w, sa, sw, abits, wbits, pc, mc, numel):
    """Quantized linear layer: act-quant(x) @ weight-quant(w).

    Routes through the fused Pallas kernel when ``mc.use_pallas`` (forward
    artifacts only — the kernel carries no custom VJP)."""
    if pc.quantized and mc.use_pallas:
        m = int(np.prod(x.shape[:-1]))
        y = qkern.qmatmul_pallas(
            x.reshape(m, x.shape[-1]), w,
            None if (pc.act_dynamic or sa is None) else jnp.broadcast_to(sa, (m,)),
            sw, abits, wbits)
        return y.reshape(x.shape[:-1] + (w.shape[-1],))
    xq = act_quant(x, sa, abits, pc, numel)
    wq = weight_quant(w, sw, wbits, pc)
    return xq @ wq


# ---------------------------------------------------------------------------
# Forward pass (optionally collecting calibration statistics)
# ---------------------------------------------------------------------------

def _percentile_stats(x):
    """[q99.91, q99.99, q99.995, max] of |x| — the calibration vector."""
    a = jnp.abs(x).reshape(-1)
    qs = jnp.percentile(a, jnp.array([99.91, 99.99, 99.995]))
    return jnp.concatenate([qs, jnp.max(a)[None]])


def _gram(x2d):
    return x2d.T @ x2d


def forward(params, tokens, mc: ModelConfig, pc: PrecisionConfig, collect_stats=False):
    """Token ids [B, S] -> logits [B, S, V] (f32).

    With ``collect_stats`` (fp16 calibration artifact) also returns the
    per-site statistics the Rust coordinator needs for quantile/max
    activation calibration, SmoothQuant channel maxima, and GPTQ Gram
    matrices.
    """
    B, S = tokens.shape
    D, F, H, dh = mc.d_model, mc.d_ff, mc.n_heads, mc.d_head
    cos, sin = rope_tables(mc)
    mask = jnp.where(
        np.tril(np.ones((S, S), np.float32))[None, None] > 0, 0.0, -1e9)
    numel = B * S * D  # per-step elements for LSQ grad scale (per layer site)

    x = params["embed"][tokens]  # embedding stays fp16/f32

    had = jnp.asarray(_hadamard(F)) if pc.online_rot else None

    block_names = [n for n in BLOCK_PARAMS if n in params]
    xs = {n: params[n] for n in block_names}

    def step(x, bp):
        def sa(name):
            return bp.get(name)

        h = rmsnorm(x, bp["ln1"])
        q = qlinear(h, bp["wq"], sa("sa_x1"), bp.get("sw_q"), pc.act_bits, pc.weight_bits, pc, mc, numel)
        k = qlinear(h, bp["wk"], sa("sa_x1"), bp.get("sw_k"), pc.act_bits, pc.weight_bits, pc, mc, numel)
        v = qlinear(h, bp["wv"], sa("sa_x1"), bp.get("sw_v"), pc.act_bits, pc.weight_bits, pc, mc, numel)

        def heads(t):
            return t.reshape(B, S, H, dh).transpose(0, 2, 1, 3)

        qh, kh, vh = heads(q), heads(k), heads(v)
        qh = apply_rope(qh, cos, sin)
        kh = apply_rope(kh, cos, sin)

        # INT16 query; C-bit KV cache (per paper Figure 2)
        qq = act_quant(qh, sa("sa_q"), pc.query_bits, pc, B * S * dh * H)
        kq = act_quant(kh, sa("sc_k"), pc.cache_bits, pc, B * S * dh * H)
        vq = act_quant(vh, sa("sc_v"), pc.cache_bits, pc, B * S * dh * H)

        scores = (qq @ kq.transpose(0, 1, 3, 2)) / np.sqrt(dh) + mask
        p = jax.nn.softmax(scores, axis=-1)  # softmax output NOT quantized
        ctx = (p @ vq).transpose(0, 2, 1, 3).reshape(B, S, D)

        o = qlinear(ctx, bp["wo"], sa("sa_o"), bp.get("sw_o"), pc.act_bits, pc.weight_bits, pc, mc, numel)
        x = x + o

        h2 = rmsnorm(x, bp["ln2"])
        gte = qlinear(h2, bp["wg"], sa("sa_x2"), bp.get("sw_g"), pc.act_bits, pc.weight_bits, pc, mc, numel)
        up = qlinear(h2, bp["wu"], sa("sa_x2"), bp.get("sw_u"), pc.act_bits, pc.weight_bits, pc, mc, numel)
        a = jax.nn.silu(gte) * up
        wd = bp["wd"]
        if pc.online_rot:
            # QuaRot-style online rotation: rotate the down-proj input and
            # counter-rotate its weight so the function is unchanged but the
            # quantized tensor has suppressed outliers.
            a = a @ had
            wd = had.T @ wd
        d = qlinear(a, wd, sa("sa_d"), bp.get("sw_d"), pc.act_bits, pc.weight_bits, pc, mc, B * S * F)
        x = x + d

        stats = None
        if collect_stats:
            h2d, ctx2d, a2d = h.reshape(-1, D), ctx.reshape(-1, D), a.reshape(-1, F)
            hh2d = h2.reshape(-1, D)
            stats = {
                "qs_x1": _percentile_stats(h), "qs_q": _percentile_stats(qh),
                "qs_k": _percentile_stats(kh), "qs_v": _percentile_stats(vh),
                "qs_o": _percentile_stats(ctx), "qs_x2": _percentile_stats(h2),
                "qs_d": _percentile_stats(a),
                "cmax_x1": jnp.max(jnp.abs(h2d), axis=0),
                "cmax_o": jnp.max(jnp.abs(ctx2d), axis=0),
                "cmax_x2": jnp.max(jnp.abs(hh2d), axis=0),
                "cmax_d": jnp.max(jnp.abs(a2d), axis=0),
                "gram_x1": _gram(h2d), "gram_o": _gram(ctx2d),
                "gram_x2": _gram(hh2d), "gram_d": _gram(a2d),
            }
        return x, stats

    x, stats = jax.lax.scan(step, x, xs)

    hf = rmsnorm(x, params["ln_f"])
    hq = act_quant(hf, params.get("sa_head"), pc.head_bits, pc, numel)
    headw = params["head"]
    if pc.quantized:
        headw = weight_quant(headw, params["sw_head"], pc.head_bits, pc)
    logits = hq @ headw

    if collect_stats:
        hf2d = hf.reshape(-1, D)
        stats["qs_head"] = _percentile_stats(hf)
        stats["cmax_head"] = jnp.max(jnp.abs(hf2d), axis=0)
        stats["gram_head"] = _gram(hf2d)
        return logits, stats
    return logits


CALIB_OUTPUTS = (
    ["qs_x1", "qs_q", "qs_k", "qs_v", "qs_o", "qs_x2", "qs_d", "qs_head"]
    + ["cmax_x1", "cmax_o", "cmax_x2", "cmax_d", "cmax_head"]
    + ["gram_x1", "gram_o", "gram_x2", "gram_d", "gram_head"]
)


# ---------------------------------------------------------------------------
# Losses + AdamW training step
# ---------------------------------------------------------------------------

def losses(logits, tokens, teacher_logits, kd_ratio, kd_temp):
    """Mixture of KD cross-entropy (teacher soft labels, Hinton) and
    next-token-prediction CE, masked on pad (id 0) targets."""
    logits, teacher_logits = logits[:, :-1], teacher_logits[:, :-1]
    tgt = tokens[:, 1:]
    m = (tgt != 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(m), 1.0)

    logp = jax.nn.log_softmax(logits, axis=-1)
    ntp_tok = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    ntp = jnp.sum(ntp_tok * m) / denom

    t = kd_temp
    pt = jax.nn.softmax(teacher_logits / t, axis=-1)
    logq = jax.nn.log_softmax(logits / t, axis=-1)
    kd_tok = -jnp.sum(pt * logq, axis=-1)
    kd = jnp.sum(kd_tok * m) / denom * t * t

    return kd_ratio * kd + (1.0 - kd_ratio) * ntp, ntp, kd


def train_step(params, m, v, tokens, teacher_logits, lr, act_lrx, kd_ratio,
               kd_temp, wd, step, mc: ModelConfig, pc: PrecisionConfig):
    """One AdamW step. ``m``/``v`` are Adam moments keyed like ``params``.

    Scalars (all runtime inputs, so one artifact serves every ablation):
    lr, act_lrx (x50 activation-step LR boost), kd_ratio, kd_temp, wd,
    step (1-based, for bias correction).
    """

    def loss_fn(p):
        logits = forward(p, tokens, mc, pc)
        loss, ntp, kd = losses(logits, tokens, teacher_logits, kd_ratio, kd_temp)
        return loss, (ntp, kd)

    (loss, (ntp, kd)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)

    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))

    t = step
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t

    new_p, new_m, new_v = {}, {}, {}
    for name in params:
        g = grads[name]
        m1 = ADAM_B1 * m[name] + (1 - ADAM_B1) * g
        v1 = ADAM_B2 * v[name] + (1 - ADAM_B2) * g * g
        upd = (m1 / bc1) / (jnp.sqrt(v1 / bc2) + ADAM_EPS)
        plr = lr * act_lrx if (name.startswith("sa_") or name.startswith("sc_")) else lr
        p1 = params[name] - plr * upd
        if name in DECAY_PARAMS:
            p1 = p1 - plr * wd * params[name]
        new_p[name], new_m[name], new_v[name] = p1, m1, v1

    return new_p, new_m, new_v, loss, gnorm, ntp, kd
