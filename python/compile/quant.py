"""Quantization primitives: STE fake-quant, LSQ learned-step quantizers,
per-token dynamic quantization, and the paper's calibration rules.

Everything here is the *training-time* (fake-quant) formulation of paper
Eq. 1:

    x_hat = round(clip(x / s, b_l, b_u)) * s

with the straight-through estimator for d x_hat / d x and the LSQ gradient
(Esser et al., 2019) for d x_hat / d s.
"""

import jax
import jax.numpy as jnp
from functools import partial

EPS = 1e-9


def qbounds(bits: int):
    """Signed symmetric integer bounds (b_l, b_u) at a given precision."""
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def _reduce_to_shape(g, shape):
    """Sum-reduce gradient ``g`` down to a broadcastable ``shape``."""
    if g.shape == tuple(shape):
        return g
    # sum over leading extra axes
    while g.ndim > len(shape):
        g = jnp.sum(g, axis=0)
    # sum over broadcast axes
    axes = tuple(i for i, (gs, ss) in enumerate(zip(g.shape, shape)) if ss == 1 and gs != 1)
    if axes:
        g = jnp.sum(g, axis=axes, keepdims=True)
    return g.reshape(shape)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def lsq_quantize(x, s, qn, qp, grad_scale):
    """LSQ fake-quantization with a learned step size ``s``.

    ``s`` must broadcast against ``x`` (scalar for per-tensor, shaped
    ``[..., 1]``-style for per-channel). ``grad_scale`` is the LSQ gradient
    scale g = 1/sqrt(N * qp).
    """
    s = jnp.maximum(s, EPS)
    v = x / s
    vbar = jnp.clip(v, qn, qp)
    return jnp.round(vbar) * s


def _lsq_fwd(x, s, qn, qp, grad_scale):
    out = lsq_quantize(x, s, qn, qp, grad_scale)
    return out, (x, s)


def _lsq_bwd(qn, qp, grad_scale, res, g):
    x, s = res
    s_safe = jnp.maximum(s, EPS)
    v = x / s_safe
    in_range = (v >= qn) & (v <= qp)
    # d x_hat / d x : straight-through inside the clip range, 0 outside.
    gx = jnp.where(in_range, g, 0.0)
    # d x_hat / d s : LSQ — (round(v) - v) inside, clip bound outside.
    ds_elem = jnp.where(in_range, jnp.round(v) - v, jnp.clip(v, qn, qp))
    gs = _reduce_to_shape(g * ds_elem, s.shape) * grad_scale
    return gx, gs


lsq_quantize.defvjp(_lsq_fwd, _lsq_bwd)


def lsq_grad_scale(numel_per_step: int, qp: int) -> float:
    """LSQ step-size gradient scale: 1 / sqrt(N * Q_p)."""
    import math

    return 1.0 / math.sqrt(max(1.0, float(numel_per_step) * float(qp)))


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_dynamic_quantize(x, bits):
    """Per-token (last-axis) dynamic symmetric quantization with STE.

    The step is recomputed from the data at every call — this is the 'd'
    mode in the paper's A8d configurations; there is no learned parameter.
    """
    qn, qp = qbounds(bits)
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qp
    s = jnp.maximum(s, EPS)
    return jnp.round(jnp.clip(x / s, qn, qp)) * s


def _dyn_fwd(x, bits):
    return ste_dynamic_quantize(x, bits), None


def _dyn_bwd(bits, _res, g):
    # Pure STE: by construction |x|/s <= qp, nothing is clipped.
    return (g,)


ste_dynamic_quantize.defvjp(_dyn_fwd, _dyn_bwd)


# ---------------------------------------------------------------------------
# Calibration (no gradients involved)
# ---------------------------------------------------------------------------

def act_step_percentile(x, bits: int, percentile: float):
    """Paper's activation calibration: step = |x| percentile / q_p."""
    _, qp = qbounds(bits)
    q = jnp.percentile(jnp.abs(x).reshape(-1), percentile)
    return jnp.maximum(q / qp, EPS)


def act_step_max(x, bits: int):
    """Max calibration (the weak baseline in the Table 4 ablation)."""
    _, qp = qbounds(bits)
    return jnp.maximum(jnp.max(jnp.abs(x)) / qp, EPS)


def weight_step_mse(w, bits: int, axis=None, iters: int = 60):
    """The paper's novel convex-MSE weight calibration (Eq. 2).

    Approximates quantization MSE as
        eps(s) = sum_i max(s^2/12, H(|w_i| - s b)(|w_i| - s b)^2),
    with b = 2^{p-1} - 0.5, and minimizes over s by ternary search (the
    objective is convex in s). ``axis`` = axes to reduce over; the
    remaining axes hold independent (per-channel) steps.
    """
    b = 2.0 ** (bits - 1) - 0.5
    aw = jnp.abs(w)
    if axis is None:
        axis = tuple(range(w.ndim))
    hi = jnp.max(aw, axis=axis, keepdims=True) / b + EPS
    lo = jnp.full_like(hi, EPS)

    def err(s):
        over = jnp.maximum(aw - s * b, 0.0)
        return jnp.sum(jnp.maximum(s * s / 12.0, over * over), axis=axis, keepdims=True)

    def body(_, carry):
        lo, hi = carry
        m1 = lo + (hi - lo) / 3.0
        m2 = hi - (hi - lo) / 3.0
        e1, e2 = err(m1), err(m2)
        lo = jnp.where(e1 > e2, m1, lo)
        hi = jnp.where(e1 > e2, hi, m2)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    s = (lo + hi) / 2.0
    return jnp.squeeze(s, axis=axis) if isinstance(axis, tuple) else s


def weight_step_lsq_init(w, bits: int, axis=None):
    """LSQ-paper initialization: s = 2 * mean|w| / sqrt(q_p)."""
    _, qp = qbounds(bits)
    if axis is None:
        axis = tuple(range(w.ndim))
    return 2.0 * jnp.mean(jnp.abs(w), axis=axis) / jnp.sqrt(float(qp)) + EPS
