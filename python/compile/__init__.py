"""Build-time JAX/Pallas compile path for the SiLQ reproduction."""
