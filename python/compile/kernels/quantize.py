"""Pallas fake-quantization kernels (L1).

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode turns them into plain HLO that
any backend (including the Rust-side PJRT CPU client) can run. Block shapes
are nevertheless chosen as they would be for a real TPU: multiples/divisors
of the 128-lane vector registers and the 128x128 MXU tile (see
DESIGN.md §Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import qbounds, EPS


def _block(dim: int, target: int = 128) -> int:
    """Largest divisor of ``dim`` that is <= target (TPU-tile friendly)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _fq_kernel(bits):
    qn, qp = qbounds(bits)

    def kernel(x_ref, s_ref, o_ref):
        s = jnp.maximum(s_ref[...], EPS)
        v = x_ref[...] / s
        o_ref[...] = jnp.round(jnp.clip(v, qn, qp)) * s

    return kernel


def fake_quant_pallas(x, s, bits: int):
    """Per-tensor fake quantization; ``s`` is a scalar (shape [1,1])."""
    m, n = x.shape
    bm, bn = _block(m), _block(n)
    s2 = jnp.asarray(s, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _fq_kernel(bits),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), s2)


def fake_quant_channel_pallas(w, sw, bits: int):
    """Per-output-channel weight fake quantization; ``sw`` has shape [N]."""
    k, n = w.shape
    bk, bn = _block(k), _block(n)
    return pl.pallas_call(
        _fq_kernel(bits),
        grid=(k // bk, n // bn),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.float32),
        interpret=True,
    )(w.astype(jnp.float32), sw.reshape(1, n).astype(jnp.float32))


def _dynq_kernel(bits):
    qn, qp = qbounds(bits)

    def kernel(x_ref, o_ref):
        x = x_ref[...]
        s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qp, EPS)
        o_ref[...] = jnp.round(jnp.clip(x / s, qn, qp)) * s

    return kernel


def dynamic_quant_pallas(x, bits: int):
    """Per-row (token) dynamic quantization. Row must fit one block, so the
    block is [bm, K] — on TPU this is the natural layout because the row
    reduction happens across lanes within VMEM."""
    m, k = x.shape
    bm = _block(m)
    return pl.pallas_call(
        _dynq_kernel(bits),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
