"""Fused quantize-matmul Pallas kernel — the paper's compute hot-spot.

Computes ``fake_quant(x) @ fake_quant_per_channel(w)`` in one pass:
activation blocks are quantized as they stream into VMEM, weight blocks are
quantized per output channel, and products accumulate in f32 — exactly the
dataflow a low-precision accelerator (NorthPole's vector-matrix unit, or a
TPU MXU fed with quantized operands) implements in hardware.

Grid is (M/bm, N/bn, K/bk): the k axis is innermost so each [bm, bn] output
tile stays resident in VMEM while K streams through — the Pallas/TPU
equivalent of the threadblock tiling the paper's GPU baselines use.

VMEM footprint per grid step (f32):
    bm*bk (x) + bk*bn (w) + bm*bn (acc) + bm (sx) + bn (sw)
At the default 128-blocks that is 3*64 KiB + 1 KiB ≈ 193 KiB — far under
the ~16 MiB VMEM budget, leaving room for double buffering (see
EXPERIMENTS.md §Perf for the footprint/utilization table).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import qbounds, EPS
from .quantize import _block


def _qmm_kernel(act_bits, weight_bits, nk):
    aqn, aqp = qbounds(act_bits)
    wqn, wqp = qbounds(weight_bits)

    def kernel(x_ref, sx_ref, w_ref, sw_ref, o_ref):
        k = pl.program_id(2)

        sx = jnp.maximum(sx_ref[...], EPS)  # [bm, 1]
        xq = jnp.round(jnp.clip(x_ref[...] / sx, aqn, aqp)) * sx
        sw = jnp.maximum(sw_ref[...], EPS)  # [1, bn]
        wq = jnp.round(jnp.clip(w_ref[...] / sw, wqn, wqp)) * sw

        acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)

        @pl.when(k == 0)
        def _init():
            o_ref[...] = acc

        @pl.when(k > 0)
        def _accum():
            o_ref[...] += acc

    return kernel


def qmatmul_pallas(x, w, sx, sw, act_bits: int, weight_bits: int,
                   bm: int = 128, bn: int = 128, bk: int = 128):
    """Fused quantized matmul.

    x: [M, K] f32; w: [K, N] f32; sw: [N] per-output-channel weight steps.
    sx: scalar step (static per-tensor), [M] per-row steps, or None for
    per-token dynamic quantization (row scales computed from |x|).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)

    _, aqp = qbounds(act_bits)
    if sx is None:  # dynamic: per-row scale from the row absmax
        sx_rows = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / aqp
    else:
        sx_arr = jnp.asarray(sx, jnp.float32)
        sx_rows = jnp.broadcast_to(sx_arr.reshape(-1, 1), (m, 1))

    return pl.pallas_call(
        _qmm_kernel(act_bits, weight_bits, k // bk),
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), sx_rows.astype(jnp.float32),
      w.astype(jnp.float32), sw.reshape(1, n).astype(jnp.float32))
