"""Pure-jnp oracles for the Pallas kernels.

These are the single source of truth for kernel numerics: every Pallas
kernel in this package is pytest-verified (with hypothesis shape/dtype
sweeps) to match these functions, and the Rust host-side `quant` module is
cross-checked against fixtures generated from them.
"""

import jax.numpy as jnp

EPS = 1e-9


def qbounds(bits: int):
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def fake_quant_ref(x, s, bits: int):
    """Symmetric fake quantization, paper Eq. 1. ``s`` broadcasts against x."""
    qn, qp = qbounds(bits)
    s = jnp.maximum(s, EPS)
    return jnp.round(jnp.clip(x / s, qn, qp)) * s


def dynamic_quant_ref(x, bits: int):
    """Per-token (last axis) dynamic symmetric quantization."""
    _, qp = qbounds(bits)
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qp, EPS)
    return fake_quant_ref(x, s, bits)


def qmatmul_ref(x, w, sx, sw, act_bits: int, weight_bits: int):
    """Fused quantized matmul oracle.

    x: [M, K] activations, quantized per tensor with step ``sx`` (scalar),
       or per row (token) dynamically when ``sx is None``.
    w: [K, N] weights, quantized per output channel with step ``sw`` [N].
    Accumulation in f32.
    """
    if sx is None:
        xq = dynamic_quant_ref(x, act_bits)
    else:
        xq = fake_quant_ref(x, sx, act_bits)
    wq = fake_quant_ref(w, sw[None, :], weight_bits)
    return jnp.dot(xq.astype(jnp.float32), wq.astype(jnp.float32))
