"""L1 Pallas kernels + pure-jnp oracles."""
from . import ref, quantize, qmatmul  # noqa: F401
