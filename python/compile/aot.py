"""AOT pipeline: lower every (model, precision, mode) artifact to HLO *text*
and write the manifest the Rust coordinator parses.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts [--only tiny_fp16_fwd]
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import ARTIFACT_MATRIX, MODELS, PRECISIONS

TRAIN_SCALARS = ["lr", "act_lrx", "kd_ratio", "kd_temp", "wd", "step"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is ESSENTIAL: the default printer elides big
    # array constants as `constant({...})`, which xla_extension 0.5.1's text
    # parser accepts silently and materializes as garbage — the RoPE tables
    # and causal mask would be destroyed.
    return comp.as_hlo_text(print_large_constants=True)


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifact(mc, pc, mode):
    """Returns (fn, in_specs, out_names) where in_specs is a list of
    (name, ShapeDtypeStruct)."""
    spec = M.param_spec(mc, pc)
    names = [n for n, _ in spec]
    pins = [(f"params.{n}", _sds(s)) for n, s in spec]

    if mode == "fwd":
        toks = ("tokens", _sds((mc.fwd_batch, mc.seq_len), jnp.int32))

        def f(*args):
            params = dict(zip(names, args[: len(names)]))
            return (M.forward(params, args[len(names)], mc, pc),)

        return f, pins + [toks], ["logits"]

    if mode == "calib":
        toks = ("tokens", _sds((mc.fwd_batch, mc.seq_len), jnp.int32))

        def f(*args):
            params = dict(zip(names, args[: len(names)]))
            logits, stats = M.forward(params, args[len(names)], mc, pc, collect_stats=True)
            # logits are returned too so every parameter (incl. the head) is
            # live — the stablehlo->XlaComputation conversion DROPS unused
            # parameters, which would desync the manifest's input list.
            return (logits,) + tuple(stats[k] for k in M.CALIB_OUTPUTS)

        return f, pins + [toks], ["logits"] + list(M.CALIB_OUTPUTS)

    if mode == "train":
        n = len(names)
        ins = (
            pins
            + [(f"m.{x}", _sds(s)) for x, s in spec]
            + [(f"v.{x}", _sds(s)) for x, s in spec]
            + [("tokens", _sds((mc.train_batch, mc.seq_len), jnp.int32))]
            + [("teacher_logits", _sds((mc.train_batch, mc.seq_len, mc.vocab)))]
            + [(x, _sds(())) for x in TRAIN_SCALARS]
        )

        def f(*args):
            p = dict(zip(names, args[:n]))
            m = dict(zip(names, args[n : 2 * n]))
            v = dict(zip(names, args[2 * n : 3 * n]))
            tokens, teacher = args[3 * n], args[3 * n + 1]
            lr, act_lrx, kd_ratio, kd_temp, wd, step = args[3 * n + 2 :]
            np_, nm, nv, loss, gnorm, ntp, kd = M.train_step(
                p, m, v, tokens, teacher, lr, act_lrx, kd_ratio, kd_temp, wd, step, mc, pc
            )
            return tuple(
                [np_[x] for x in names]
                + [nm[x] for x in names]
                + [nv[x] for x in names]
                + [loss, gnorm, ntp, kd]
            )

        outs = (
            [f"params.{x}" for x in names]
            + [f"m.{x}" for x in names]
            + [f"v.{x}" for x in names]
            + ["loss", "gnorm", "ntp", "kd"]
        )
        return f, ins, outs

    raise ValueError(mode)


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[str(dt)]


def _shape_tag(shape) -> str:
    return "scalar" if len(shape) == 0 else "x".join(str(d) for d in shape)


def lower_one(name, mc, pc, mode, out_dir, manifest_lines, force=False):
    fn, ins, out_names = build_artifact(mc, pc, mode)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    if force or not os.path.exists(path):
        lowered = jax.jit(fn, keep_unused=True).lower(*[s for _, s in ins])
        text = to_hlo_text(lowered)
        with open(path, "w") as fh:
            fh.write(text)
        print(f"  wrote {path} ({len(text) / 1e6:.2f} MB, {len(ins)} inputs)")
    else:
        print(f"  cached {path}")

    manifest_lines.append(
        f"artifact {name} file={name}.hlo.txt model={mc.name} prec={pc.name} mode={mode}"
    )
    # re-derive output shapes via eval_shape so cached artifacts still get
    # complete manifest entries.
    out_shapes = jax.eval_shape(fn, *[s for _, s in ins])
    for n, s in ins:
        manifest_lines.append(f"in {n} {_dtype_tag(s.dtype)} {_shape_tag(s.shape)}")
    for n, s in zip(out_names, out_shapes):
        manifest_lines.append(f"out {n} {_dtype_tag(s.dtype)} {_shape_tag(s.shape)}")
    manifest_lines.append("endartifact")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    lines = ["# silq artifact manifest v1"]
    for mc in MODELS.values():
        lines.append(
            f"model {mc.name} vocab={mc.vocab} d_model={mc.d_model} "
            f"n_layers={mc.n_layers} n_heads={mc.n_heads} d_ff={mc.d_ff} "
            f"seq_len={mc.seq_len} train_batch={mc.train_batch} fwd_batch={mc.fwd_batch} "
            f"use_pallas={int(mc.use_pallas)}"
        )
    for pc in PRECISIONS.values():
        lines.append(
            f"prec {pc.name} quantized={int(pc.quantized)} act_bits={pc.act_bits} "
            f"act_dynamic={int(pc.act_dynamic)} cache_bits={pc.cache_bits} "
            f"weight_bits={pc.weight_bits} head_bits={pc.head_bits} "
            f"query_bits={pc.query_bits} online_rot={int(pc.online_rot)}"
        )

    for size, prec, mode in ARTIFACT_MATRIX:
        name = f"{size}_{prec}_{mode}"
        if args.only and args.only not in name:
            continue
        print(f"lowering {name} ...")
        lower_one(name, MODELS[size], PRECISIONS[prec], mode, args.out_dir, lines,
                  force=args.force)

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as fh:
        fh.write("\n".join(lines) + "\n")
    print(f"manifest: {len(lines)} lines")


if __name__ == "__main__":
    sys.exit(main())
