"""Model / precision configurations shared by the JAX model and the AOT pipeline.

Names here are the contract with the Rust coordinator: every artifact is
identified as ``{size}_{precision}_{mode}`` and the manifest written by
``aot.py`` records the exact input/output tensor order for each artifact.
"""

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    """Architecture of the decoder-only transformer (Llama-style).

    d_model and d_ff are kept powers of two so the online-Hadamard rotation
    ablation (QuaRot-style) has well-defined Hadamard matrices.
    """

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    train_batch: int
    fwd_batch: int
    rope_theta: float = 10000.0
    use_pallas: bool = False  # route linear layers through the Pallas kernel

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class PrecisionConfig:
    """Quantization placement, mirroring the paper's Figure 2.

    - activations feeding every linear / matmul: ``act_bits`` (8)
    - query and softmax-output matmul inputs: INT16 (softmax output is left
      unquantized during training, exactly as in paper section 3.2)
    - KV cache: ``cache_bits`` (4 or 8)
    - weights: ``weight_bits`` (4), per output channel
    - final head: 8-bit input and weights; embedding stays fp16/f32
    """

    name: str
    quantized: bool = True
    act_bits: int = 8
    act_dynamic: bool = True  # True = per-token dynamic ('d'), False = static learned ('s')
    cache_bits: int = 8
    weight_bits: int = 4
    head_bits: int = 8
    query_bits: int = 16
    online_rot: bool = False  # QuaRot-style online Hadamard before down-proj (Table 4 ablation)


FP16 = PrecisionConfig(name="fp16", quantized=False)
A8D_C8_W4 = PrecisionConfig(name="a8d-c8-w4", act_dynamic=True, cache_bits=8)
A8S_C8_W4 = PrecisionConfig(name="a8s-c8-w4", act_dynamic=False, cache_bits=8)
A8D_C4_W4 = PrecisionConfig(name="a8d-c4-w4", act_dynamic=True, cache_bits=4)
A8D_C8_W4_ROT = replace(A8D_C8_W4, name="a8d-c8-w4-rot", online_rot=True)

PRECISIONS = {p.name: p for p in [FP16, A8D_C8_W4, A8S_C8_W4, A8D_C4_W4, A8D_C8_W4_ROT]}

# Percentiles for activation-step calibration, per paper section 3.1:
# 99.91 / 99.99 / 99.995 for 4- / 8- / 16-bit activations.
CALIB_PERCENTILES = {4: 99.91, 8: 99.99, 16: 99.995}

TINY = ModelConfig(
    name="tiny", vocab=256, d_model=128, n_layers=4, n_heads=4, d_ff=256,
    seq_len=64, train_batch=16, fwd_batch=32,
)
SMALL = ModelConfig(
    name="small", vocab=512, d_model=256, n_layers=8, n_heads=8, d_ff=512,
    seq_len=128, train_batch=8, fwd_batch=16,
)
# tiny variant that routes its linears through the Pallas kernel; proves the
# L1->L2->L3 composition end to end (see DESIGN.md section 3).
TINY_PALLAS = replace(TINY, name="tiny-pallas", use_pallas=True, n_layers=2)

MODELS = {m.name: m for m in [TINY, SMALL, TINY_PALLAS]}

# Which (model, precision, mode) triples `make artifacts` builds.
ARTIFACT_MATRIX = [
    # tiny: full experiment grid
    ("tiny", "fp16", "fwd"),
    ("tiny", "fp16", "train"),
    ("tiny", "fp16", "calib"),
    ("tiny", "a8d-c8-w4", "fwd"),
    ("tiny", "a8d-c8-w4", "train"),
    ("tiny", "a8s-c8-w4", "fwd"),
    ("tiny", "a8s-c8-w4", "train"),
    ("tiny", "a8d-c4-w4", "fwd"),
    ("tiny", "a8d-c4-w4", "train"),
    ("tiny", "a8d-c8-w4-rot", "fwd"),
    ("tiny", "a8d-c8-w4-rot", "train"),
    # small: e2e showcase
    ("small", "fp16", "fwd"),
    ("small", "fp16", "train"),
    ("small", "fp16", "calib"),
    ("small", "a8d-c8-w4", "fwd"),
    ("small", "a8d-c8-w4", "train"),
    # pallas-composed variant (L1 kernels inside the lowered HLO)
    ("tiny-pallas", "a8d-c8-w4", "fwd"),
]
