"""Quantizer semantics: STE/LSQ gradients, dynamic quant, calibration rules."""

import numpy as np
import jax
import jax.numpy as jnp
# real hypothesis when installed; offline, stubs that skip only the
# property tests so the plain unit tests below still run
from _hyp import given, settings, st

from compile import quant


def test_qbounds():
    assert quant.qbounds(4) == (-8, 7)
    assert quant.qbounds(8) == (-128, 127)
    assert quant.qbounds(16) == (-32768, 32767)
    assert quant.qbounds(2) == (-2, 1)


def test_lsq_forward_matches_eq1():
    x = jnp.asarray([-3.0, -0.26, -0.24, 0.0, 0.26, 10.0])
    s = jnp.asarray(0.5)
    y = quant.lsq_quantize(x, s, -8, 7, 1.0)
    # round(clip(x/s, -8, 7)) * s
    np.testing.assert_allclose(y, [-1.5 * 2, -0.5, -0.0, 0.0, 0.5, 3.5], atol=1e-6)


def test_lsq_grad_x_is_ste_with_clipping():
    s = jnp.asarray(0.5)
    x = jnp.asarray([-10.0, 0.2, 10.0])  # below, inside, above the clip range
    g = jax.grad(lambda x: jnp.sum(quant.lsq_quantize(x, s, -8, 7, 1.0)))(x)
    np.testing.assert_allclose(g, [0.0, 1.0, 0.0], atol=1e-6)


def test_lsq_grad_s_formula():
    """d xhat/d s = round(v)-v inside range, clip bound outside (LSQ eq. 2)."""
    s = jnp.asarray(1.0)
    for xv, expect in [(0.3, np.round(0.3) - 0.3), (7.4, 7.0), (-9.0, -8.0), (100.0, 7.0)]:
        g = jax.grad(lambda s: jnp.sum(quant.lsq_quantize(jnp.asarray([xv]), s, -8, 7, 1.0)))(s)
        np.testing.assert_allclose(g, expect, atol=1e-5)


def test_lsq_grad_s_scale_applied():
    x = jnp.asarray([0.3, 0.3])
    base = jax.grad(lambda s: jnp.sum(quant.lsq_quantize(x, s, -8, 7, 1.0)))(jnp.asarray(1.0))
    half = jax.grad(lambda s: jnp.sum(quant.lsq_quantize(x, s, -8, 7, 0.5)))(jnp.asarray(1.0))
    np.testing.assert_allclose(half, base * 0.5, atol=1e-6)


def test_lsq_per_channel_step():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((16, 4)).astype(np.float32))
    s = jnp.asarray([0.1, 0.2, 0.3, 0.4])[None, :]
    y = quant.lsq_quantize(w, s, -8, 7, 1.0)
    for c in range(4):
        ratio = np.asarray(y[:, c]) / float(s[0, c])
        np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8]))
def test_dynamic_quant_error_bound(seed, bits):
    """Per-token dynamic quantization error is bounded by s/2 per element."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((8, 32)).astype(np.float32) * 5)
    y = quant.ste_dynamic_quantize(x, bits)
    _, qp = quant.qbounds(bits)
    s = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True) / qp
    assert np.all(np.abs(np.asarray(y - x)) <= s / 2 + 1e-6)


def test_dynamic_quant_grad_is_identity():
    x = jnp.asarray([[1.0, -2.0, 3.0]])
    g = jax.grad(lambda x: jnp.sum(quant.ste_dynamic_quantize(x, 8) * 2.0))(x)
    np.testing.assert_allclose(g, 2.0 * np.ones_like(x), atol=1e-6)


def test_act_step_percentile_vs_max():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(100000).astype(np.float32))
    sp = quant.act_step_percentile(x, 8, 99.99)
    sm = quant.act_step_max(x, 8)
    assert float(sp) < float(sm)  # percentile clips the outlier tail
    assert float(sp) > 0


def test_weight_step_mse_matches_bruteforce():
    rng = np.random.default_rng(2)
    w = rng.standard_normal(512).astype(np.float32)
    s = float(quant.weight_step_mse(jnp.asarray(w), 4))
    b = 2.0 ** 3 - 0.5

    def eps(sv):
        over = np.maximum(np.abs(w) - sv * b, 0.0)
        return np.sum(np.maximum(sv * sv / 12.0, over * over))

    grid = np.linspace(1e-4, np.abs(w).max() / b, 4000)
    best = grid[np.argmin([eps(sv) for sv in grid])]
    assert abs(s - best) / best < 0.02


def test_weight_step_mse_beats_max_scaling_mse():
    """The convex-MSE step should give lower true quantization MSE than
    naive max-scaling for heavy-tailed weights (the reason the paper
    introduces it)."""
    rng = np.random.default_rng(3)
    w = jnp.asarray((rng.standard_t(df=3, size=4096) * 0.05).astype(np.float32))
    _, qp = quant.qbounds(4)

    def mse(s):
        y = quant.lsq_quantize(w, jnp.asarray(s), -8, 7, 1.0)
        return float(jnp.mean((y - w) ** 2))

    s_mse = float(quant.weight_step_mse(w, 4))
    s_max = float(jnp.max(jnp.abs(w)) / qp)
    assert mse(s_mse) < mse(s_max)


def test_weight_step_mse_per_channel_shape():
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32))
    s = quant.weight_step_mse(w, 4, axis=(0,))
    assert s.shape == (8,)
    assert np.all(np.asarray(s) > 0)


def test_weight_step_lsq_init():
    w = jnp.asarray(np.ones(100, np.float32))
    s = quant.weight_step_lsq_init(w, 4)
    np.testing.assert_allclose(float(s), 2.0 / np.sqrt(7.0), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mse_objective_convexity_witness(seed):
    """eps(s) evaluated on a grid is unimodal (sanity for ternary search)."""
    rng = np.random.default_rng(seed)
    w = np.abs(rng.standard_normal(256)).astype(np.float32)
    b = 2.0 ** 3 - 0.5
    grid = np.linspace(1e-4, w.max() / b * 1.5, 200)
    vals = []
    for sv in grid:
        over = np.maximum(w - sv * b, 0.0)
        vals.append(np.sum(np.maximum(sv * sv / 12.0, over * over)))
    vals = np.array(vals)
    k = int(np.argmin(vals))
    assert np.all(np.diff(vals[: k + 1]) <= 1e-3) and np.all(np.diff(vals[k:]) >= -1e-3)
