"""L1 correctness: Pallas kernels vs the pure-jnp oracles (hypothesis sweeps)."""

import numpy as np
import jax.numpy as jnp
import pytest

# real hypothesis when installed; offline, stubs that skip only the
# property tests so the plain unit tests below still run
from _hyp import given, settings, st

from compile.kernels import ref, quantize, qmatmul

DIMS = st.sampled_from([8, 16, 32, 64, 128, 192, 256])
BITS = st.sampled_from([2, 4, 8, 16])
SEEDS = st.integers(0, 2**31 - 1)


def _rand(rng, *shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, bits=BITS, seed=SEEDS)
def test_fake_quant_matches_ref(m, n, bits, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, n)
    s = float(abs(rng.standard_normal()) * 0.1 + 1e-3)
    got = quantize.fake_quant_pallas(x, s, bits)
    want = ref.fake_quant_ref(jnp.asarray(x), s, bits)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(k=DIMS, n=DIMS, bits=BITS, seed=SEEDS)
def test_fake_quant_channel_matches_ref(k, n, bits, seed):
    rng = np.random.default_rng(seed)
    w = _rand(rng, k, n)
    sw = (np.abs(rng.standard_normal(n)) * 0.1 + 1e-3).astype(np.float32)
    got = quantize.fake_quant_channel_pallas(w, sw, bits)
    want = ref.fake_quant_ref(jnp.asarray(w), jnp.asarray(sw)[None, :], bits)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, bits=BITS, seed=SEEDS)
def test_dynamic_quant_matches_ref(m, n, bits, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, n, scale=3.0)
    got = quantize.dynamic_quant_pallas(x, bits)
    want = ref.dynamic_quant_ref(jnp.asarray(x), bits)
    # a 1-ulp difference in the row scale can flip a rounding bin; allow up
    # to one step of error per element (the bulk must still match exactly).
    step = np.abs(x).max() / (2 ** (bits - 1) - 1)
    diff = np.abs(np.asarray(got) - np.asarray(want))
    assert diff.max() <= step * 1.001 + 1e-6
    assert np.mean(diff > 1e-6) < 0.02


@settings(max_examples=20, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=SEEDS,
       abits=st.sampled_from([4, 8, 16]), wbits=st.sampled_from([2, 4, 8]))
def test_qmatmul_static_matches_ref(m, k, n, seed, abits, wbits):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    sw = (np.abs(rng.standard_normal(n)) * 0.05 + 1e-3).astype(np.float32)
    sx = float(abs(rng.standard_normal()) * 0.05 + 1e-3)
    got = qmatmul.qmatmul_pallas(x, w, sx, sw, abits, wbits)
    want = ref.qmatmul_ref(jnp.asarray(x), jnp.asarray(w), sx, jnp.asarray(sw), abits, wbits)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=SEEDS)
def test_qmatmul_dynamic_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k, scale=2.0), _rand(rng, k, n)
    sw = (np.abs(rng.standard_normal(n)) * 0.05 + 1e-3).astype(np.float32)
    got = qmatmul.qmatmul_pallas(x, w, None, sw, 8, 4)
    want = ref.qmatmul_ref(jnp.asarray(x), jnp.asarray(w), None, jnp.asarray(sw), 8, 4)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_qmatmul_rejects_shape_mismatch():
    x = np.zeros((8, 16), np.float32)
    w = np.zeros((8, 16), np.float32)
    with pytest.raises(AssertionError):
        qmatmul.qmatmul_pallas(x, w, 0.1, np.ones(16, np.float32), 8, 4)


def test_block_helper_divides():
    for dim in [8, 24, 100, 128, 640, 1000]:
        b = quantize._block(dim)
        assert dim % b == 0 and b <= 128


def test_fake_quant_grid_levels():
    """Quantized values must land on the step grid within the clip range."""
    rng = np.random.default_rng(0)
    x = _rand(rng, 32, 64, scale=2.0)
    s = 0.07
    y = np.asarray(quantize.fake_quant_pallas(x, s, 4))
    ratio = y / s
    np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-4)
    assert ratio.min() >= -8 - 1e-4 and ratio.max() <= 7 + 1e-4
