"""L2 model: shapes, quantization-site wiring, losses, and the train step."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import configs as C


def _params(mc, pc, seed=0):
    return {k: jnp.asarray(v) for k, v in M.init_params(mc, pc, seed).items()}


def _tokens(mc, batch, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(1, mc.vocab, (batch, mc.seq_len)), jnp.int32)


MC = C.TINY


class TestParamSpec:
    def test_fp16_has_no_quant_params(self):
        names = [n for n, _ in M.param_spec(MC, C.FP16)]
        assert not any(n.startswith(("sw_", "sa_", "sc_")) for n in names)

    def test_dynamic_has_weight_steps_only(self):
        names = [n for n, _ in M.param_spec(MC, C.A8D_C8_W4)]
        assert "sw_q" in names and "sw_head" in names
        assert not any(n.startswith(("sa_", "sc_")) for n in names)

    def test_static_has_act_and_cache_steps(self):
        names = [n for n, _ in M.param_spec(MC, C.A8S_C8_W4)]
        for n in ("sa_x1", "sa_q", "sc_k", "sc_v", "sa_o", "sa_x2", "sa_d", "sa_head"):
            assert n in names

    def test_shapes_are_stacked_per_layer(self):
        spec = dict(M.param_spec(MC, C.A8S_C8_W4))
        L, D, F, V = MC.n_layers, MC.d_model, MC.d_ff, MC.vocab
        assert spec["wq"] == (L, D, D)
        assert spec["wd"] == (L, F, D)
        assert spec["sw_d"] == (L, D)       # per *output* channel of down-proj
        assert spec["sw_head"] == (V,)
        assert spec["sa_x1"] == (L,)
        assert spec["sa_head"] == ()


class TestForward:
    @pytest.mark.parametrize("pcname", ["fp16", "a8d-c8-w4", "a8s-c8-w4", "a8d-c4-w4", "a8d-c8-w4-rot"])
    def test_logits_shape_and_finite(self, pcname):
        pc = C.PRECISIONS[pcname]
        logits = M.forward(_params(MC, pc), _tokens(MC, 4), MC, pc)
        assert logits.shape == (4, MC.seq_len, MC.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_quantization_changes_output(self):
        pf, pq = C.FP16, C.A8D_C4_W4
        p = _params(MC, pq)
        pf_params = {k: v for k, v in p.items() if not k.startswith(("sw_", "sa_", "sc_"))}
        lf = M.forward(pf_params, _tokens(MC, 2), MC, pf)
        lq = M.forward(p, _tokens(MC, 2), MC, pq)
        assert float(jnp.max(jnp.abs(lf - lq))) > 1e-4

    def test_causality(self):
        """Changing a future token must not change past logits."""
        pc = C.FP16
        p = _params(MC, pc)
        t1 = _tokens(MC, 1)
        t2 = t1.at[0, -1].set((t1[0, -1] % (MC.vocab - 1)) + 1)
        l1 = M.forward(p, t1, MC, pc)
        l2 = M.forward(p, t2, MC, pc)
        np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], atol=1e-5)

    def test_online_rotation_approx_preserves_fp_function(self):
        """With quantization off, H then H^T is an exact no-op."""
        pc_rot = C.PrecisionConfig(name="fp-rot", quantized=False, online_rot=True)
        p = _params(MC, C.FP16)
        l0 = M.forward(p, _tokens(MC, 2), MC, C.FP16)
        l1 = M.forward(p, _tokens(MC, 2), MC, pc_rot)
        np.testing.assert_allclose(l0, l1, atol=2e-4)

    def test_calib_stats_shapes(self):
        pc = C.FP16
        _, stats = M.forward(_params(MC, pc), _tokens(MC, 4), MC, pc, collect_stats=True)
        L, D, F = MC.n_layers, MC.d_model, MC.d_ff
        assert stats["qs_x1"].shape == (L, 4)
        assert stats["qs_head"].shape == (4,)
        assert stats["cmax_d"].shape == (L, F)
        assert stats["gram_x1"].shape == (L, D, D)
        assert stats["gram_d"].shape == (L, F, F)
        assert set(M.CALIB_OUTPUTS) == set(stats.keys())

    def test_calib_quantiles_ordered(self):
        pc = C.FP16
        _, stats = M.forward(_params(MC, pc), _tokens(MC, 4), MC, pc, collect_stats=True)
        q = np.asarray(stats["qs_x1"])
        assert np.all(np.diff(q, axis=1) >= -1e-6)  # q99.91 <= q99.99 <= q99.995 <= max

    def test_gram_matrices_psd(self):
        pc = C.FP16
        _, stats = M.forward(_params(MC, pc), _tokens(MC, 4), MC, pc, collect_stats=True)
        g = np.asarray(stats["gram_x1"][0])
        np.testing.assert_allclose(g, g.T, atol=1e-3)
        assert np.linalg.eigvalsh(g).min() > -1e-2


class TestLosses:
    def test_ntp_matches_manual_ce(self):
        rng = np.random.default_rng(0)
        B, S, V = 2, 8, 16
        logits = jnp.asarray(rng.standard_normal((B, S, V)).astype(np.float32))
        tokens = jnp.asarray(rng.integers(1, V, (B, S)), jnp.int32)
        loss, ntp, _ = M.losses(logits, tokens, jnp.zeros((B, S, V)), 0.0, 1.0)
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        manual = -np.mean([lp[b, s, tokens[b, s + 1]] for b in range(B) for s in range(S - 1)])
        np.testing.assert_allclose(float(ntp), manual, rtol=1e-5)
        np.testing.assert_allclose(float(loss), float(ntp), rtol=1e-6)

    def test_kd_zero_when_student_equals_teacher_argmax(self):
        """KD loss equals teacher entropy when student == teacher."""
        rng = np.random.default_rng(1)
        B, S, V = 2, 8, 16
        logits = jnp.asarray(rng.standard_normal((B, S, V)).astype(np.float32))
        tokens = jnp.asarray(rng.integers(1, V, (B, S)), jnp.int32)
        _, _, kd = M.losses(logits, tokens, logits, 1.0, 1.0)
        pt = jax.nn.softmax(logits[:, :-1], axis=-1)
        ent = float(jnp.mean(-jnp.sum(pt * jnp.log(pt + 1e-20), axis=-1)))
        np.testing.assert_allclose(float(kd), ent, rtol=1e-4)

    def test_pad_positions_masked(self):
        rng = np.random.default_rng(2)
        B, S, V = 1, 8, 16
        logits = jnp.asarray(rng.standard_normal((B, S, V)).astype(np.float32))
        t1 = jnp.asarray(rng.integers(1, V, (B, S)), jnp.int32)
        t2 = t1.at[0, 4:].set(0)  # pad the tail
        l1, _, _ = M.losses(logits, t1, jnp.zeros((B, S, V)), 0.0, 1.0)
        l2, _, _ = M.losses(logits, t2, jnp.zeros((B, S, V)), 0.0, 1.0)
        assert not np.isclose(float(l1), float(l2))
        assert np.isfinite(float(l2))

    def test_temperature_scaling(self):
        rng = np.random.default_rng(3)
        B, S, V = 2, 8, 16
        logits = jnp.asarray(rng.standard_normal((B, S, V)).astype(np.float32))
        teacher = jnp.asarray(rng.standard_normal((B, S, V)).astype(np.float32))
        tokens = jnp.asarray(rng.integers(1, V, (B, S)), jnp.int32)
        _, _, kd1 = M.losses(logits, tokens, teacher, 1.0, 1.0)
        _, _, kd2 = M.losses(logits, tokens, teacher, 1.0, 2.0)
        assert float(kd1) != float(kd2)


class TestTrainStep:
    def _setup(self, pc):
        p = _params(MC, pc)
        m = {k: jnp.zeros_like(v) for k, v in p.items()}
        v = {k: jnp.zeros_like(x) for k, x in p.items()}
        toks = _tokens(MC, MC.train_batch)
        teacher = jnp.asarray(
            np.random.default_rng(9).standard_normal((MC.train_batch, MC.seq_len, MC.vocab)),
            jnp.float32)
        return p, m, v, toks, teacher

    def test_ntp_loss_decreases(self):
        pc = C.FP16
        p, m, v, toks, teacher = self._setup(pc)
        step = jax.jit(lambda *a: M.train_step(*a, MC, pc))
        losses = []
        for i in range(8):
            p, m, v, loss, gnorm, ntp, kd = step(p, m, v, toks, teacher, 3e-3, 1.0, 0.0, 1.0, 0.0, float(i + 1))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.9

    def test_kd_loss_decreases_quantized(self):
        pc = C.A8S_C8_W4
        p, m, v, toks, teacher = self._setup(pc)
        step = jax.jit(lambda *a: M.train_step(*a, MC, pc))
        losses = []
        for i in range(8):
            p, m, v, loss, gnorm, ntp, kd = step(p, m, v, toks, teacher, 3e-3, 50.0, 1.0, 1.0, 0.0, float(i + 1))
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_static_act_steps_move_with_boost(self):
        pc = C.A8S_C8_W4
        p, m, v, toks, teacher = self._setup(pc)
        step = jax.jit(lambda *a: M.train_step(*a, MC, pc))
        p1, *_ = step(p, m, v, toks, teacher, 1e-3, 50.0, 1.0, 1.0, 0.0, 1.0)
        p0, *_ = step(p, m, v, toks, teacher, 1e-3, 1.0, 1.0, 1.0, 0.0, 1.0)
        d_boost = float(jnp.max(jnp.abs(p1["sa_x1"] - p["sa_x1"])))
        d_plain = float(jnp.max(jnp.abs(p0["sa_x1"] - p["sa_x1"])))
        assert d_boost > d_plain * 5  # lr x50 on activation steps

    def test_weight_decay_only_on_weights(self):
        pc = C.A8S_C8_W4
        p, m, v, toks, teacher = self._setup(pc)
        # two steps differing only in wd; ln/steps should be identical
        a = M.train_step(p, m, v, toks, teacher, 1e-3, 1.0, 1.0, 1.0, 0.0, 1.0, MC, pc)
        b = M.train_step(p, m, v, toks, teacher, 1e-3, 1.0, 1.0, 1.0, 0.5, 1.0, MC, pc)
        np.testing.assert_allclose(a[0]["ln1"], b[0]["ln1"], atol=1e-7)
        np.testing.assert_allclose(a[0]["sa_x1"], b[0]["sa_x1"], atol=1e-7)
        assert float(jnp.max(jnp.abs(a[0]["wq"] - b[0]["wq"]))) > 1e-6

    def test_gnorm_positive_finite(self):
        pc = C.A8D_C8_W4
        p, m, v, toks, teacher = self._setup(pc)
        out = M.train_step(p, m, v, toks, teacher, 1e-3, 50.0, 1.0, 1.0, 0.1, 1.0, MC, pc)
        g = float(out[4])
        assert np.isfinite(g) and g > 0


class TestPallasComposition:
    def test_pallas_fwd_matches_ref_model(self):
        """tiny-pallas forward (L1 kernels inside) == jnp reference path."""
        mc = C.TINY_PALLAS
        mc_ref = C.ModelConfig(**{**mc.__dict__, "name": "tp-ref", "use_pallas": False})
        pc = C.A8D_C8_W4
        p = _params(mc, pc)
        toks = _tokens(mc, 2)
        lp = np.asarray(M.forward(p, toks, mc, pc))
        lr_ = np.asarray(M.forward(p, toks, mc_ref, pc))
        diff = np.abs(lp - lr_)
        # fake-quant is discontinuous: a 1-ulp accumulation-order difference
        # between the tiled Pallas matmul and the monolithic jnp dot can flip
        # an isolated round() bin downstream. Require agreement everywhere
        # except a tiny fraction of single-bin flips of bounded size.
        assert np.median(diff) < 1e-5
        assert np.mean(diff > 1e-3) < 0.05
        assert diff.max() < 0.05
