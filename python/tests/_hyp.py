"""hypothesis, or graceful offline stubs.

The offline image does not ship ``hypothesis``. Importing ``given``,
``settings`` and ``st`` from here lets a test module keep its plain unit
tests runnable while only the ``@given`` property tests are skipped.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:

    class _St:
        """Stand-in for ``hypothesis.strategies``: every strategy is inert
        (its result is only ever consumed by the ``given`` stub below)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _St()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis unavailable offline")

    def settings(*_a, **_k):
        return lambda f: f
