"""AOT pipeline: manifest consistency and HLO-text round-trip sanity."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M
from compile.configs import ARTIFACT_MATRIX, MODELS, PRECISIONS, TINY

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_artifact_matrix_names_unique():
    names = [f"{s}_{p}_{m}" for s, p, m in ARTIFACT_MATRIX]
    assert len(names) == len(set(names))


def test_matrix_references_known_configs():
    for s, p, m in ARTIFACT_MATRIX:
        assert s in MODELS and p in PRECISIONS and m in ("fwd", "train", "calib")


def test_build_artifact_shapes_fwd():
    fn, ins, outs = aot.build_artifact(TINY, PRECISIONS["fp16"], "fwd")
    shapes = jax.eval_shape(fn, *[s for _, s in ins])
    assert outs == ["logits"]
    assert shapes[0].shape == (TINY.fwd_batch, TINY.seq_len, TINY.vocab)


def test_build_artifact_train_io_symmetry():
    """train outputs mirror params/m/v inputs exactly (order and shape)."""
    fn, ins, outs = aot.build_artifact(TINY, PRECISIONS["a8s-c8-w4"], "train")
    nparams = len(M.param_spec(TINY, PRECISIONS["a8s-c8-w4"]))
    assert len(ins) == 3 * nparams + 2 + len(aot.TRAIN_SCALARS)
    assert len(outs) == 3 * nparams + 4
    in_names = [n for n, _ in ins]
    assert in_names[:nparams] == outs[:nparams]
    shapes = jax.eval_shape(fn, *[s for _, s in ins])
    for (name, sds), out_sds in zip(ins[: 3 * nparams], shapes):
        assert sds.shape == out_sds.shape, name


def test_build_artifact_calib_outputs():
    fn, ins, outs = aot.build_artifact(TINY, PRECISIONS["fp16"], "calib")
    assert outs == ["logits"] + list(M.CALIB_OUTPUTS)
    shapes = jax.eval_shape(fn, *[s for _, s in ins])
    assert len(shapes) == len(outs)


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.txt")),
                    reason="artifacts not built")
def test_manifest_covers_matrix():
    text = open(os.path.join(ART, "manifest.txt")).read()
    for s, p, m in ARTIFACT_MATRIX:
        assert f"artifact {s}_{p}_{m} " in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.txt")),
                    reason="artifacts not built")
def test_manifest_artifact_files_exist():
    for line in open(os.path.join(ART, "manifest.txt")):
        if line.startswith("artifact "):
            fname = [f for f in line.split() if f.startswith("file=")][0][5:]
            assert os.path.exists(os.path.join(ART, fname)), fname


def test_hlo_text_lowering_small_fn():
    """The HLO-text interchange survives a lower->text round trip."""
    fn = lambda x, y: (jnp.matmul(x, y) + 1.0,)
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text and "dot" in text


def test_scalar_and_shape_tags():
    assert aot._shape_tag(()) == "scalar"
    assert aot._shape_tag((2, 3)) == "2x3"
    assert aot._dtype_tag(np.dtype("float32")) == "f32"
    assert aot._dtype_tag(np.dtype("int32")) == "i32"
