#!/usr/bin/env bash
# Tier-1 verification gate (ROADMAP.md): release build, full Rust test
# suite, and formatting. Run from the repository root: ./scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release --offline

echo "== cargo test -q =="
cargo test -q --offline

echo "== cargo test -q --release (integration + property suites) =="
# the identity sweeps and the serve soak are too slow to size fully in
# debug (the batched≡sequential sweep and the soak scale down via
# cfg!(debug_assertions)); this release pass runs the suites where the
# integer kernels are fast. The long-seed soak stays out of the gate —
# run it via `make soak`.
cargo test -q --offline --release \
  --test proptests --test serve_integration --test serve_soak \
  --test kernels_integration --test kernels_zero_alloc --test obs_integration

echo "== kernel identity + serve suites at SILQ_THREADS=1 and =4 =="
# every identity pin must hold bit-exactly at any worker-pool width: run
# the kernel identity and serve property suites serial and sharded
for t in 1 4; do
  echo "-- SILQ_THREADS=$t --"
  SILQ_THREADS=$t cargo test -q --offline --release \
    --test proptests --test kernels_integration --test serve_soak
done

echo "== trace export smoke (--trace / --metrics-out) =="
# a real serve run must emit valid Chrome-trace and metrics JSON whose
# top-level shape downstream tooling (Perfetto, dashboards) can load
TRACE_OUT="$(mktemp /tmp/silq_smoke.XXXXXX.trace.json)"
METRICS_OUT="$(mktemp /tmp/silq_smoke.XXXXXX.metrics.json)"
cargo run -q --release --offline -- serve \
  --requests 8 --batch 2 --max_new 4 --producers 1 --prec w4a8kv8 \
  --trace "$TRACE_OUT" --metrics-out "$METRICS_OUT" > /dev/null
python3 - "$TRACE_OUT" "$METRICS_OUT" <<'EOF'
import json, sys
trace = json.load(open(sys.argv[1]))
assert trace["traceEvents"], "trace has no events"
assert all(e["ph"] == "X" for e in trace["traceEvents"]), "non-complete event"
assert trace["counters"]["serve_completed"] == 8, trace["counters"]
metrics = json.load(open(sys.argv[2]))
assert metrics["schema"] == "silq.metrics.v1", metrics.get("schema")
assert len(metrics["steps"]) == metrics["totals"]["steps"], "series/total mismatch"
assert metrics["totals"]["completed"] == 8, metrics["totals"]
print("trace smoke: OK "
      f"({len(trace['traceEvents'])} events, {len(metrics['steps'])} steps)")
EOF
rm -f "$TRACE_OUT" "$METRICS_OUT"

echo "== cargo clippy -D warnings =="
cargo clippy --offline --all-targets -- -D warnings

echo "== cargo doc -D warnings =="
# the public policy/forward/serve APIs must stay documented (broken
# intra-doc links and missing docs fail the gate)
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --offline --quiet

echo "== cargo fmt --check =="
cargo fmt --check

echo "tier-1 check: OK"
