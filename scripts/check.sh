#!/usr/bin/env bash
# Tier-1 verification gate (ROADMAP.md): release build, full Rust test
# suite, and formatting. Run from the repository root: ./scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release --offline

echo "== cargo test -q =="
cargo test -q --offline

echo "== cargo test -q --release (integration + property suites) =="
# the identity sweeps and the serve soak are too slow to size fully in
# debug (the batched≡sequential sweep and the soak scale down via
# cfg!(debug_assertions)); this release pass runs the suites where the
# integer kernels are fast. The long-seed soak stays out of the gate —
# run it via `make soak`.
cargo test -q --offline --release \
  --test proptests --test serve_integration --test serve_soak \
  --test kernels_integration --test kernels_zero_alloc --test obs_integration \
  --test net_integration --test net_soak --test chaos_soak

echo "== kernel identity + serve suites at SILQ_THREADS=1 and =4 =="
# every identity pin must hold bit-exactly at any worker-pool width: run
# the kernel identity and serve property suites serial and sharded
for t in 1 4; do
  echo "-- SILQ_THREADS=$t --"
  SILQ_THREADS=$t cargo test -q --offline --release \
    --test proptests --test kernels_integration --test serve_soak --test net_soak
done

echo "== trace export smoke (--trace / --metrics-out) =="
# a real serve run must emit valid Chrome-trace and metrics JSON whose
# top-level shape downstream tooling (Perfetto, dashboards) can load
TRACE_OUT="$(mktemp /tmp/silq_smoke.XXXXXX.trace.json)"
METRICS_OUT="$(mktemp /tmp/silq_smoke.XXXXXX.metrics.json)"
cargo run -q --release --offline -- serve \
  --requests 8 --batch 2 --max_new 4 --producers 1 --prec w4a8kv8 \
  --trace "$TRACE_OUT" --metrics-out "$METRICS_OUT" > /dev/null
python3 - "$TRACE_OUT" "$METRICS_OUT" <<'EOF'
import json, sys
trace = json.load(open(sys.argv[1]))
assert trace["traceEvents"], "trace has no events"
assert all(e["ph"] == "X" for e in trace["traceEvents"]), "non-complete event"
assert trace["counters"]["serve_completed"] == 8, trace["counters"]
metrics = json.load(open(sys.argv[2]))
assert metrics["schema"] == "silq.metrics.v1", metrics.get("schema")
assert len(metrics["steps"]) == metrics["totals"]["steps"], "series/total mismatch"
assert metrics["totals"]["completed"] == 8, metrics["totals"]
print("trace smoke: OK "
      f"({len(trace['traceEvents'])} events, {len(metrics['steps'])} steps)")
EOF
rm -f "$TRACE_OUT" "$METRICS_OUT"

echo "== paged-vs-slab identity smoke (--kv) =="
# the paged KV pool must decode token-for-token what the slab decodes,
# through the real scheduler: the same seeded load runs once per layout
# (prompts are fixed per request id before the producer split, so the
# id-sorted --tokens-out dumps must be byte-identical)
SLAB_TOK="$(mktemp /tmp/silq_smoke.XXXXXX.slab.tokens)"
PAGED_TOK="$(mktemp /tmp/silq_smoke.XXXXXX.paged.tokens)"
cargo run -q --release --offline -- serve \
  --requests 16 --batch 4 --max_new 6 --producers 2 --prec w4a8kv8 \
  --kv slab --tokens-out "$SLAB_TOK" > /dev/null
cargo run -q --release --offline -- serve \
  --requests 16 --batch 4 --max_new 6 --producers 2 --prec w4a8kv8 \
  --kv paged --page-size 8 --tokens-out "$PAGED_TOK" > /dev/null
diff "$SLAB_TOK" "$PAGED_TOK" \
  || { echo "paged decode diverged from the slab"; exit 1; }
echo "kv identity smoke: OK (16 token streams identical)"
rm -f "$SLAB_TOK" "$PAGED_TOK"

echo "== serve-over-HTTP smoke (silq serve --listen) =="
# end to end over a real socket: start the server on an ephemeral port,
# stream one SSE completion, check /healthz and the live /metrics schema,
# then drain through POST /shutdown and require a clean exit
SERVE_LOG="$(mktemp /tmp/silq_smoke.XXXXXX.serve.log)"
cargo run -q --release --offline -- serve \
  --listen 127.0.0.1:0 --batch 2 --prec w4a8kv8 > "$SERVE_LOG" &
SERVE_PID=$!
for _ in $(seq 1 100); do
  grep -q "listening on " "$SERVE_LOG" && break
  sleep 0.1
done
ADDR="$(sed -n 's/^listening on \([0-9.:]*\) .*/\1/p' "$SERVE_LOG" | head -n1)"
if [ -z "$ADDR" ]; then
  kill "$SERVE_PID" 2>/dev/null || true
  echo "http smoke: server never came up"; cat "$SERVE_LOG"; exit 1
fi
if ! python3 - "$ADDR" <<'EOF'
import json, socket, sys

host, port = sys.argv[1].rsplit(":", 1)

def req(method, path, body=b""):
    s = socket.create_connection((host, int(port)), timeout=30)
    s.sendall((f"{method} {path} HTTP/1.1\r\nHost: smoke\r\n"
               f"Content-Type: application/json\r\nContent-Length: {len(body)}\r\n"
               f"Connection: close\r\n\r\n").encode() + body)
    data = b""
    while True:
        chunk = s.recv(65536)
        if not chunk:
            break
        data += chunk
    s.close()
    head, _, rest = data.partition(b"\r\n\r\n")
    return head, rest

def dechunk(b):
    out = b""
    while b:
        line, _, b = b.partition(b"\r\n")
        n = int(line, 16)
        if n == 0:
            break
        out += b[:n]
        b = b[n + 2:]
    return out

head, body = req("GET", "/healthz")
assert b" 200 " in head.split(b"\r\n", 1)[0], head
assert json.loads(body)["status"] == "ok", body

head, body = req("POST", "/v1/completions", json.dumps(
    {"id": 1, "prompt": [1, 2, 3], "max_tokens": 4,
     "ignore_eos": True, "stream": True}).encode())
assert b" 200 " in head.split(b"\r\n", 1)[0], head
assert b"text/event-stream" in head, head
frames = [json.loads(f[6:]) for f in dechunk(body).split(b"\n\n")
          if f.strip().startswith(b"data: ")]
tokens = [f["token"] for f in frames if "token" in f]
done = [f for f in frames if f.get("done")]
assert len(tokens) == 4, frames
assert done and done[0]["generated"] == tokens, frames
assert done[0]["ttft_ms"] is not None and done[0]["error"] is None, frames

head, body = req("GET", "/metrics")
m = json.loads(body)
assert m["schema"] == "silq.metrics.v1", m.get("schema")
assert m["wire_ttft"]["count"] >= 1, m["wire_ttft"]
assert m["counters"]["net_streams"] >= 1, m["counters"]

head, body = req("POST", "/shutdown")
assert json.loads(body)["draining"] is True, body
print(f"http smoke: OK ({len(tokens)} tokens streamed, "
      f"{m['counters']['net_requests']} wire requests)")
EOF
then
  kill "$SERVE_PID" 2>/dev/null || true
  echo "http smoke failed"; cat "$SERVE_LOG"; exit 1
fi
wait "$SERVE_PID"
grep -q "drained clean" "$SERVE_LOG" || { echo "no clean drain"; cat "$SERVE_LOG"; exit 1; }
rm -f "$SERVE_LOG"

echo "== resilience smoke (--faults, deadlines, health recovery) =="
# the armed fault plan must surface on the wire exactly once (one forced
# 429 with a backoff hint), an expired TTFT deadline must shed with 503,
# and /healthz must walk ok -> degraded -> ok -> draining around the storm
CHAOS_LOG="$(mktemp /tmp/silq_smoke.XXXXXX.chaos.log)"
cargo run -q --release --offline -- serve \
  --listen 127.0.0.1:0 --batch 2 --prec w4a8kv8 --faults full@2 > "$CHAOS_LOG" &
CHAOS_PID=$!
for _ in $(seq 1 100); do
  grep -q "listening on " "$CHAOS_LOG" && break
  sleep 0.1
done
ADDR="$(sed -n 's/^listening on \([0-9.:]*\) .*/\1/p' "$CHAOS_LOG" | head -n1)"
if [ -z "$ADDR" ]; then
  kill "$CHAOS_PID" 2>/dev/null || true
  echo "resilience smoke: server never came up"; cat "$CHAOS_LOG"; exit 1
fi
if ! python3 - "$ADDR" <<'EOF'
import json, socket, sys

host, port = sys.argv[1].rsplit(":", 1)

def req(method, path, body=b""):
    s = socket.create_connection((host, int(port)), timeout=30)
    s.sendall((f"{method} {path} HTTP/1.1\r\nHost: smoke\r\n"
               f"Content-Type: application/json\r\nContent-Length: {len(body)}\r\n"
               f"Connection: close\r\n\r\n").encode() + body)
    data = b""
    while True:
        chunk = s.recv(65536)
        if not chunk:
            break
        data += chunk
    s.close()
    head, _, rest = data.partition(b"\r\n\r\n")
    return head, rest

def status(head):
    return int(head.split(b"\r\n", 1)[0].split(b" ")[1])

def header(head, name):
    for line in head.split(b"\r\n")[1:]:
        k, _, v = line.partition(b":")
        if k.strip().lower() == name.lower().encode():
            return v.strip().decode()
    return None

def post(doc):
    return req("POST", "/v1/completions", json.dumps(doc).encode())

def healthz():
    head, body = req("GET", "/healthz")
    assert status(head) == 200, head
    return json.loads(body)

assert healthz()["status"] == "ok"

# submit 1: serves normally
head, body = post({"id": 1, "prompt": [1, 2, 3], "max_tokens": 4,
                   "ignore_eos": True, "priority": "interactive"})
assert status(head) == 200 and len(json.loads(body)["generated"]) == 4, body

# submit 2: the armed full@2 forces queue-full -> 429 with a backoff hint
head, body = post({"id": 2, "prompt": [4, 5], "max_tokens": 4, "ignore_eos": True})
assert status(head) == 429, head
assert int(header(head, "Retry-After")) >= 1, head
assert json.loads(body)["retry_after_ms"] >= 1, body

# submit 3: the retry is accepted (the fault fires once)
head, body = post({"id": 2, "prompt": [4, 5], "max_tokens": 4, "ignore_eos": True})
assert status(head) == 200 and len(json.loads(body)["generated"]) == 4, body

# submit 4: an already-expired TTFT deadline is shed, never decoded
head, body = post({"id": 3, "prompt": [6], "max_tokens": 4,
                   "ignore_eos": True, "ttft_deadline_ms": 0})
assert status(head) == 503, head
doc = json.loads(body)
assert doc["reason"] == "deadline_shed" and doc["retry_after_ms"] >= 1, body
assert int(header(head, "Retry-After")) >= 1, head

# the shed leaves pressure behind: degraded, with the miss on record
hz = healthz()
assert hz["status"] == "degraded" and hz["deadline_misses"] >= 1, hz

# submit 5: healthy decode steps drain the pressure back to ok
head, body = post({"id": 4, "prompt": [7, 8], "max_tokens": 8, "ignore_eos": True})
assert status(head) == 200 and len(json.loads(body)["generated"]) == 8, body
assert healthz()["status"] == "ok", healthz()

head, body = req("POST", "/shutdown")
assert json.loads(body)["draining"] is True, body
print("resilience smoke: OK (429 hinted, 503 shed, health ok->degraded->ok)")
EOF
then
  kill "$CHAOS_PID" 2>/dev/null || true
  echo "resilience smoke failed"; cat "$CHAOS_LOG"; exit 1
fi
wait "$CHAOS_PID"
grep -q "drained clean" "$CHAOS_LOG" || { echo "no clean drain"; cat "$CHAOS_LOG"; exit 1; }
rm -f "$CHAOS_LOG"

echo "== bench-serve smoke (wire bench rows) =="
# the wire bench must produce parseable rows with the TTFT percentiles
# and provenance fields populated
BENCH_OUT="$(mktemp /tmp/silq_smoke.XXXXXX.bench.json)"
cargo run -q --release --offline -- bench-serve \
  --clients 1,2 --per_client 2 --max_new 4 --prec w4a8kv8 --out "$BENCH_OUT" > /dev/null
python3 - "$BENCH_OUT" <<'EOF'
import json, sys
rows = json.load(open(sys.argv[1]))
assert len(rows) == 2, rows
for r in rows:
    assert r["mode"] == "closed" and r["backend"] == "host+http", r
    assert r["completed"] == r["clients"] * 2 and r["dropped"] == 0, r
    assert r["wire_ttft_ms_p50"] > 0 and r["wire_ttft_ms_p95"] >= r["wire_ttft_ms_p50"], r
    assert r["tok_per_s"] > 0 and r["threads"] >= 1 and r["kernel"], r
print(f"bench-serve smoke: OK ({len(rows)} rows)")
EOF
rm -f "$BENCH_OUT"

echo "== cargo clippy -D warnings =="
cargo clippy --offline --all-targets -- -D warnings

echo "== cargo doc -D warnings =="
# the public policy/forward/serve APIs must stay documented (broken
# intra-doc links and missing docs fail the gate)
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --offline --quiet

echo "== cargo fmt --check =="
cargo fmt --check

echo "tier-1 check: OK"
