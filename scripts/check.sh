#!/usr/bin/env bash
# Tier-1 verification gate (ROADMAP.md): release build, full Rust test
# suite, and formatting. Run from the repository root: ./scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release --offline

echo "== cargo test -q =="
cargo test -q --offline

echo "== cargo test -q --release (integration + property suites) =="
# the identity sweeps and the serve soak are too slow to size fully in
# debug (the batched≡sequential sweep and the soak scale down via
# cfg!(debug_assertions)); this release pass runs the suites where the
# integer kernels are fast. The long-seed soak stays out of the gate —
# run it via `make soak`.
cargo test -q --offline --release \
  --test proptests --test serve_integration --test serve_soak \
  --test kernels_integration --test kernels_zero_alloc

echo "== cargo clippy -D warnings =="
cargo clippy --offline --all-targets -- -D warnings

echo "== cargo doc -D warnings =="
# the public policy/forward/serve APIs must stay documented (broken
# intra-doc links and missing docs fail the gate)
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --offline --quiet

echo "== cargo fmt --check =="
cargo fmt --check

echo "tier-1 check: OK"
