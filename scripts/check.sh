#!/usr/bin/env bash
# Tier-1 verification gate (ROADMAP.md): release build, full Rust test
# suite, and formatting. Run from the repository root: ./scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release --offline

echo "== cargo test -q =="
cargo test -q --offline

echo "== cargo clippy -D warnings =="
cargo clippy --offline --all-targets -- -D warnings

echo "== cargo doc -D warnings =="
# the public policy/forward/serve APIs must stay documented (broken
# intra-doc links and missing docs fail the gate)
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --offline --quiet

echo "== cargo fmt --check =="
cargo fmt --check

echo "tier-1 check: OK"
